"""Tiled LU factorization (no pivoting; callers supply diagonally-dominant
matrices) as a SLATE-style task graph with gang-scheduled panel regions.

Structure per step ``k`` (paper Fig. 5/6):

* ``panel[k]``  — ONE heavy task forking a nested parallel region
  (:func:`~repro.linalg.panels.lu_panel_region`, two blocking barriers per
  column) — the region the paper gang-schedules,
* ``bcast[k]``  — send the factored panel to the other ranks (comm task),
* ``col[k+1,k]`` — the lookahead column update (critical path),
* ``trail*[k]`` — trailing parent creating one child per remaining column
  (``U_kj = L_kk^{-1} A_kj`` then ``A_ij -= L_ik U_kj``), joined for the next
  step's dependencies.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..api.graph import Graph
from ..compile.fuse import FuseSpec
from ..core.taskgraph import ParallelSpec, TaskGraph
from .cholesky import SPAWN_COST
from .panels import lu_panel_region
from .tiles import (
    CostModel,
    ShapeOnlyStore,
    TileStore,
    tile_gemm_nn_sub,
    tile_trsm_left_lower_unit,
)


def _lu_col_fused(lkk, akj, *pairs):
    """Fused column update: ``U_kj = L_kk^{-1} A_kj`` then ``A_ij -= L_ik
    U_kj`` for the interleaved ``(L_ik, A_ij)`` pairs.  Module-level so
    compiled plans cache one jitted callable per column shape."""
    ukj = tile_trsm_left_lower_unit(lkk, akj)
    outs = [ukj]
    for t in range(0, len(pairs), 2):
        outs.append(tile_gemm_nn_sub(pairs[t + 1], pairs[t], ukj))
    return outs[0] if len(outs) == 1 else tuple(outs)


def build_lu_graph(
    nb: int,
    b: int = 64,
    *,
    store: Optional[TileStore] = None,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    gang_panels: Optional[bool] = None,
    comm: bool = True,
) -> TaskGraph:
    cm = cost or CostModel()
    g = Graph(f"lu[{nb}x{nb},b={b}]")
    numeric = store is not None
    noop = (lambda ctx: None) if numeric else None

    def panel_body_factory(k: int, n_threads: int):
        """Numeric panel task: gathers block column k, forks the gang region,
        scatters the factored tiles back."""
        def fn(ctx):
            panel = np.concatenate(
                [np.asarray(store[(i, k)]) for i in range(k, store.nb)], axis=0)
            body = lu_panel_region(panel, store.b, n_threads)
            ctx.parallel(n_threads, body, gang=gang_panels)
            for idx, i in enumerate(range(k, store.nb)):
                store[(i, k)] = jnp.asarray(panel[idx * store.b:(idx + 1) * store.b])
        return fn

    if numeric:
        g.fuse_state = store

    def col_body(j: int, k: int):
        def fn(ctx):
            store[(k, j)] = tile_trsm_left_lower_unit(store[(k, k)], store[(k, j)])
            for i in range(k + 1, store.nb):
                store[(i, j)] = tile_gemm_nn_sub(store[(i, j)], store[(i, k)], store[(k, j)])
        return fn if numeric else None

    def col_fuse(j: int, k: int):
        if not numeric:
            return None
        reads = [(k, k), (k, j)]
        writes = [(k, j)]
        for i in range(k + 1, nb):
            reads += [(i, k), (i, j)]
            writes.append((i, j))
        return FuseSpec(_lu_col_fused, tuple(reads), tuple(writes))

    def col_cost(k: int) -> float:
        return cm.trsm(b) + 2.0 * (nb - k - 1) * b ** 3 / cm.flop_rate

    join_look = None
    join_trail = None

    for k in range(nb):
        m_tiles = nb - k
        n_threads = max(1, min(panel_threads, m_tiles))
        pdeps = [join_look] if join_look is not None else []
        if numeric:
            p = g.add(panel_body_factory(k, n_threads), name=f"panel[{k}]",
                      kind="panel", cost=cm.panel_lu(m_tiles, b), priority=3,
                      deps=pdeps, step=k)
        else:
            p = g.add(None, name=f"panel[{k}]", kind="panel",
                      cost=0.05 * cm.panel_lu(m_tiles, b), priority=3, deps=pdeps,
                      parallel=ParallelSpec(
                          n_threads=n_threads,
                          cost_per_thread=cm.panel_lu(m_tiles, b) / n_threads,
                          n_barriers=2 * b, blocking=True),
                      step=k)

        col_dep = p
        if comm:
            col_dep = g.add(noop, name=f"bcast[{k}]", kind="comm",
                            cost=cm.bcast(m_tiles, b, ranks), priority=3,
                            deps=[p], step=k)
        base_deps = [col_dep] + ([join_trail] if join_trail is not None else [])

        # lookahead column (single task, critical path)
        if k + 1 < nb:
            join_look = g.add(col_body(k + 1, k), name=f"col[{k + 1},{k}]",
                              kind="lookahead", cost=col_cost(k), priority=2,
                              deps=base_deps, step=k, fuse=col_fuse(k + 1, k))
        else:
            join_look = None

        # trailing family
        if k + 2 < nb:
            tparent = g.add(noop, name=f"trail*[{k}]", kind="compute",
                            cost=SPAWN_COST * (nb - k - 2), priority=0,
                            deps=base_deps, step=k)
            tchildren = [
                g.add(col_body(j, k), name=f"col[{j},{k}]", kind="compute",
                      cost=col_cost(k), priority=0, deps=[tparent], step=k,
                      fuse=col_fuse(j, k))
                for j in range(k + 2, nb)
            ]
            join_trail = g.add(noop, name=f"trail.join[{k}]", kind="compute",
                               cost=0.0, priority=0, deps=tchildren, step=k)
        else:
            join_trail = None
    return g


def lu_graph_key(
    nb: int,
    b: int = 64,
    *,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    comm: bool = True,
):
    """Structural replay-cache key for :func:`build_lu_graph`.  NOTE: numeric
    and cost-model LU builds differ structurally (the cost-model panel is a
    :class:`ParallelSpec` task, the numeric panel forks at run time), so
    record numeric sweeps against a numeric build's key — this helper exists
    for simulator/cost-model replay."""
    from ..replay import graph_key
    return graph_key(build_lu_graph(nb, b, cost=cost, ranks=ranks,
                                    panel_threads=panel_threads, comm=comm))


def lu_static_recording(
    nb: int,
    b: int = 64,
    *,
    n_workers: int,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    panel_threads: int = 4,
    comm: bool = True,
    policy: str = "hybrid",
    seed: int = 0,
):
    """Synthesize a replay :class:`~repro.replay.Recording` for the
    **numeric** LU graph from the simulator: the cost-model twin (same
    structure, :class:`ParallelSpec` panels) is list-scheduled at
    ``n_workers``, its gang reservations become recorded placements (panel
    forks replay *placed*, not via dynamic fallback), and the recording is
    keyed to the numeric build's digest so numeric sweeps replay it
    directly."""
    from ..core.static_schedule import ListScheduler
    from ..replay.graph_key import graph_key
    from ..replay.recording import Recording

    kwargs = dict(cost=cost, ranks=ranks, panel_threads=panel_threads,
                  comm=comm)
    twin = build_lu_graph(nb, b, **kwargs)
    sched = ListScheduler(n_workers, policy=policy, seed=seed).schedule(twin)
    numeric_key = graph_key(
        build_lu_graph(nb, b, store=ShapeOnlyStore(nb, b), **kwargs))
    return Recording.from_static_schedule(sched, twin, key=numeric_key)


def lu_extract(store: TileStore):
    """Assemble (L_unit, U) from the packed in-place factorization."""
    a = store.assemble()
    l = jnp.tril(a, -1) + jnp.eye(a.shape[0], dtype=a.dtype)
    u = jnp.triu(a)
    return l, u


def random_diagdom(n: int, seed: int = 0, dtype=jnp.float64) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n))
    m += np.diag(np.abs(m).sum(axis=1) + 1.0)
    return jnp.asarray(m, dtype=dtype)
