"""Multithreaded panel factorizations — the nested data-parallel regions the
paper gang-schedules (SLATE §5.2: "the panel factorization is parallelized in
a nested-parallel region ... synchronized at the end of each step using a
custom barrier operation in the library").

Each panel body runs as a gang ULT: ``body(thread_num, region)`` over a
shared numpy buffer, with ``region.barrier()`` as the blocking in-region
synchronization.  Threads own block-rows round-robin (the paper: "each
thread is persistently assigned tiles in a round-robin manner").
"""

from __future__ import annotations

from typing import List

import numpy as np


def _row_ranges(m: int, b: int, n_threads: int, tid: int, lead: int = 0) -> List[slice]:
    """Row slices (as slices into the panel) owned by ``tid``: block-rows of
    height ``b`` assigned round-robin, skipping the first ``lead`` rows where
    requested by the caller."""
    out = []
    n_blocks = (m + b - 1) // b
    for blk in range(tid, n_blocks, n_threads):
        r0, r1 = blk * b, min((blk + 1) * b, m)
        out.append(slice(r0, r1))
    return out


def lu_panel_region(panel: np.ndarray, b: int, n_threads: int):
    """Return ``body(tid, region)`` factoring ``panel`` (m x w) in place into
    unit-lower L (below diagonal) and U (on/above), *without pivoting*
    (callers guarantee diagonal dominance).  Right-looking, two blocking
    barriers per column — the paper's custom-barrier pattern."""
    m, w = panel.shape

    def body(tid: int, region) -> None:
        my_rows = _row_ranges(m, b, n_threads, tid)
        for j in range(w):
            # 1) scale column j below the diagonal (own rows only)
            pjj = panel[j, j]
            for sl in my_rows:
                lo = max(sl.start, j + 1)
                if lo < sl.stop:
                    panel[lo:sl.stop, j] /= pjj
            region.barrier()
            # 2) rank-1 update of the trailing columns (own rows only)
            if j + 1 < w:
                prow = panel[j, j + 1:]
                for sl in my_rows:
                    lo = max(sl.start, j + 1)
                    if lo < sl.stop:
                        panel[lo:sl.stop, j + 1:] -= np.outer(panel[lo:sl.stop, j], prow)
            region.barrier()

    return body


def qr_panel_region(panel: np.ndarray, b: int, n_threads: int):
    """Return ``(body, taus)``: Householder panel factorization of ``panel``
    (m x w) in place — V (unit lower) below the diagonal, R on/above — with
    per-column reductions synchronized by blocking barriers (4 per column).
    ``taus[j]`` filled with the Householder scalars."""
    m, w = panel.shape
    taus = np.zeros(w)
    # shared scratch: per-thread partial reductions
    norm_part = np.zeros(n_threads)
    w_part = np.zeros((n_threads, w))
    w_red = np.zeros(w)

    def body(tid: int, region) -> None:
        my_rows = _row_ranges(m, b, n_threads, tid)
        for j in range(w):
            # (a) partial squared norms of column j below row j
            s = 0.0
            for sl in my_rows:
                lo = max(sl.start, j + 1)
                if lo < sl.stop:
                    seg = panel[lo:sl.stop, j]
                    s += float(seg @ seg)
            norm_part[tid] = s
            region.barrier()
            # (b) thread 0 forms the reflector: v=[1, x/(alpha-beta)], tau
            if tid == 0:
                alpha = panel[j, j]
                sigma = float(norm_part.sum())
                if sigma == 0.0:
                    taus[j] = 0.0
                else:
                    beta = -np.sign(alpha if alpha != 0 else 1.0) * np.sqrt(alpha * alpha + sigma)
                    taus[j] = (beta - alpha) / beta
                    panel[j, j] = beta
                    norm_part[0] = alpha - beta   # broadcast the scale factor
            region.barrier()
            if taus[j] != 0.0:
                scale = norm_part[0]
                # (c) scale own rows of v; partial w = v^T A for trailing cols
                for sl in my_rows:
                    lo = max(sl.start, j + 1)
                    if lo < sl.stop:
                        panel[lo:sl.stop, j] /= scale
                part = np.zeros(w - j - 1) if j + 1 < w else np.zeros(0)
                for sl in my_rows:
                    lo = max(sl.start, j + 1)
                    if lo < sl.stop and j + 1 < w:
                        part += panel[lo:sl.stop, j] @ panel[lo:sl.stop, j + 1:]
                if j + 1 < w:
                    # v[0] = 1 contribution comes from row j (owned by its block owner)
                    if any(sl.start <= j < sl.stop for sl in my_rows):
                        part += panel[j, j + 1:]
                    w_part[tid, j + 1:] = part
                region.barrier()
                # (d) thread 0 reduces w
                if tid == 0 and j + 1 < w:
                    w_red[j + 1:] = taus[j] * w_part[:, j + 1:].sum(axis=0)
                region.barrier()
                # (e) apply rank-1 update to own rows (row j handled by owner)
                if j + 1 < w:
                    for sl in my_rows:
                        if sl.start <= j < sl.stop:
                            panel[j, j + 1:] -= w_red[j + 1:]
                        lo = max(sl.start, j + 1)
                        if lo < sl.stop:
                            panel[lo:sl.stop, j + 1:] -= np.outer(
                                panel[lo:sl.stop, j], w_red[j + 1:])
            else:
                region.barrier()
                region.barrier()
            region.barrier()

    return body, taus


def qr_form_t(panel: np.ndarray, taus: np.ndarray) -> np.ndarray:
    """Build the compact-WY T factor (upper triangular, w x w) from V (unit
    lower in ``panel``) and ``taus``: H_0 H_1 ... = I - V T V^T."""
    m, w = panel.shape
    V = np.tril(panel, -1)[:, :w] + np.eye(m, w)
    T = np.zeros((w, w))
    for j in range(w):
        if taus[j] == 0.0:
            continue
        T[j, j] = taus[j]
        if j > 0:
            T[:j, j] = -taus[j] * (T[:j, :j] @ (V[:, :j].T @ V[:, j]))
    return T
