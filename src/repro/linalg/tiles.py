"""Tiled-matrix utilities and the analytical cost model for the SLATE-style
factorization task graphs."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Key = Tuple[int, int]


class TileStore:
    """Shared tile storage mutated by task bodies.  Task-graph dependencies
    guarantee exclusive access ordering; dict item assignment is atomic."""

    def __init__(self, tiles: Dict[Key, jnp.ndarray], nb: int, b: int):
        self.tiles = tiles
        self.nb = nb
        self.b = b

    def __getitem__(self, k: Key) -> jnp.ndarray:
        return self.tiles[k]

    def __setitem__(self, k: Key, v: jnp.ndarray) -> None:
        self.tiles[k] = v

    def assemble(self) -> jnp.ndarray:
        rows = []
        for i in range(self.nb):
            rows.append(jnp.concatenate([self.tiles[(i, j)] for j in range(self.nb)], axis=1))
        return jnp.concatenate(rows, axis=0)


class ShapeOnlyStore:
    """Stand-in for a :class:`TileStore` carrying only ``(nb, b)``.  Task
    bodies never run against it — it exists so the *numeric* variant of a
    factorization graph can be built purely for its structural
    :func:`~repro.replay.graph_key` (numeric and cost-model builds differ
    structurally)."""

    def __init__(self, nb: int, b: int):
        self.nb = nb
        self.b = b


def to_tiles(a: jnp.ndarray, b: int) -> TileStore:
    n = a.shape[0]
    if a.shape[0] != a.shape[1] or n % b != 0:
        raise ValueError(f"need square matrix with dim divisible by {b}, got {a.shape}")
    nb = n // b
    tiles = {
        (i, j): jnp.asarray(a[i * b:(i + 1) * b, j * b:(j + 1) * b])
        for i in range(nb) for j in range(nb)
    }
    return TileStore(tiles, nb, b)


@dataclasses.dataclass
class CostModel:
    """Analytical per-task costs for the simulator / static scheduler.

    Defaults approximate one Skylake core (paper's testbed: 2x20C Skylake)
    and EDR InfiniBand: the absolute scale is irrelevant for the relative
    policy comparisons; the compute/comm *ratio* is what matters.
    """

    flop_rate: float = 40e9        # effective flops/s per worker (DGEMM-ish)
    panel_flop_rate: float = 12e9  # panel kernels are bandwidth/latency bound
    comm_bw: float = 10e9          # bytes/s inter-rank link
    comm_latency: float = 15e-6    # per-message latency
    dtype_bytes: int = 8

    def gemm(self, b: int) -> float:
        return 2.0 * b ** 3 / self.flop_rate

    def syrk(self, b: int) -> float:
        return 1.0 * b ** 3 / self.flop_rate

    def trsm(self, b: int) -> float:
        return 1.0 * b ** 3 / self.flop_rate

    def potrf(self, b: int) -> float:
        return (b ** 3 / 3.0) / self.panel_flop_rate

    def panel_lu(self, m_tiles: int, b: int) -> float:
        # left-looking panel on m_tiles*b x b block column
        return (m_tiles * b * b * b) / self.panel_flop_rate

    def panel_qr(self, m_tiles: int, b: int) -> float:
        return (2.0 * m_tiles * b * b * b) / self.panel_flop_rate

    def tile_bytes(self, b: int) -> int:
        return b * b * self.dtype_bytes

    def bcast(self, n_tiles: int, b: int, ranks: int = 4) -> float:
        # pipelined broadcast of a factored block column to the other ranks
        return self.comm_latency * max(1, ranks - 1) + \
            n_tiles * self.tile_bytes(b) / self.comm_bw


# ---------------------------------------------------------------------------
# jitted tile kernels (CPU path; the TPU hot-spot versions live in
# repro.kernels with Pallas implementations)
# ---------------------------------------------------------------------------
@jax.jit
def tile_potrf(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.cholesky(a)


@jax.jit
def tile_trsm_right_lower_t(a: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    """Solve X L^T = A for X (the Cholesky column update)."""
    # X = A L^{-T}  =>  X^T = L^{-1} A^T
    return jax.scipy.linalg.solve_triangular(l, a.T, lower=True).T


@jax.jit
def tile_gemm_sub(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C - A @ B^T (trailing update)."""
    return c - a @ b.T


@jax.jit
def tile_gemm_nn_sub(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C - A @ B."""
    return c - a @ b


@jax.jit
def tile_trsm_left_lower_unit(l: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Solve L X = A with unit-diagonal lower L (LU row update)."""
    return jax.scipy.linalg.solve_triangular(l, a, lower=True, unit_diagonal=True)
