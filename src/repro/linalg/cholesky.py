"""Tiled right-looking Cholesky factorization as a SLATE-style task graph.

Structure per step ``k`` — mirroring SLATE's nesting (top-level tasks with
``omp depend`` at block-column granularity, each *creating child tasks* and
taskwait-ing on them):

* ``panel*[k]``   — parent task; children: ``potrf[k]`` then independent
                    ``trsm[i,k]`` ("panel factorization is done in a bunch of
                    independent tasks", §5.4); joined by ``panel.join[k]``,
* ``bcast[k]``    — blocking communication: ship the factored column,
* ``look*[k]``    — lookahead parent; children update block column ``k+1``,
* ``trail*[k]``   — trailing parent; children update columns ``k+2..``.

The victim-selection anomaly the paper fixes lives in this shape: a trailing
parent dumps its many children onto *one* worker's queue; history-based
thieves lock onto that queue and the panel's children (and the broadcast
behind them) serialize on whatever worker picked the panel up — delaying the
critical path.  Hybrid stealing spreads the panel children (paper Fig. 9/11).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..api.graph import Graph
from ..compile.fuse import FuseSpec
from ..core.taskgraph import TaskGraph
from .tiles import CostModel, TileStore, tile_gemm_sub, tile_potrf, tile_trsm_right_lower_t

# per-child task-creation overhead charged to parent tasks (OpenMP task
# creation is ~0.5-1us)
SPAWN_COST = 7e-7


def build_cholesky_graph(
    nb: int,
    b: int = 64,
    *,
    store: Optional[TileStore] = None,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    comm: bool = True,
) -> TaskGraph:
    """Build the tiled-Cholesky task graph.  If ``store`` is given, tasks
    carry numeric bodies factoring it in place (lower-triangular result);
    otherwise bodies are ``None`` (cost-model graphs for the simulator).

    Built through the v2 :class:`~repro.api.Graph` (``add`` returns
    :class:`~repro.api.TaskHandle` futures usable as ``deps=``); tile
    writes are ordered by the explicit edges, so the structure — and the
    replay-cache digest — is identical to the v1 construction."""
    cm = cost or CostModel()
    g = Graph(f"cholesky[{nb}x{nb},b={b}]")
    numeric = store is not None
    noop = (lambda ctx: None) if numeric else None
    if numeric:
        # fuse metadata: numeric bodies are pure tile kernels over the store,
        # declared so compiled plans can fuse runs of them into one jitted
        # segment (Task.meta is digest-neutral — recordings are unaffected)
        g.fuse_state = store

    def _fuse(kernel, reads, writes):
        return FuseSpec(kernel, tuple(reads), tuple(writes)) if numeric else None

    def potrf_body(k):
        def fn(ctx):
            store[(k, k)] = tile_potrf(store[(k, k)])
        return fn if numeric else None

    def trsm_body(i, k):
        def fn(ctx):
            store[(i, k)] = tile_trsm_right_lower_t(store[(i, k)], store[(k, k)])
        return fn if numeric else None

    def update_body(i, j, k):
        def fn(ctx):
            store[(i, j)] = tile_gemm_sub(store[(i, j)], store[(i, k)], store[(j, k)])
        return fn if numeric else None

    join_look = None     # join of lookahead[k-1] (column k final)
    join_trail = None    # join of trailing[k-1]

    for k in range(nb):
        # ---- panel family -------------------------------------------------
        n_children = nb - k
        pparent = g.add(noop, name=f"panel*[{k}]", kind="panel",
                        cost=SPAWN_COST * n_children, priority=3,
                        deps=[join_look] if join_look is not None else [], step=k)
        potrf = g.add(potrf_body(k), name=f"potrf[{k}]", kind="panel",
                      cost=cm.potrf(b), priority=3, deps=[pparent], step=k,
                      fuse=_fuse(tile_potrf, [(k, k)], [(k, k)]))
        trsms = [
            g.add(trsm_body(i, k), name=f"trsm[{i},{k}]", kind="panel",
                  cost=cm.trsm(b), priority=3, deps=[potrf], step=k,
                  fuse=_fuse(tile_trsm_right_lower_t, [(i, k), (k, k)], [(i, k)]))
            for i in range(k + 1, nb)
        ]
        pjoin = g.add(noop, name=f"panel.join[{k}]", kind="panel", cost=0.0,
                      priority=3, deps=trsms or [potrf], step=k)

        col_dep = pjoin
        if comm:
            col_dep = g.add(noop, name=f"bcast[{k}]", kind="comm",
                            cost=cm.bcast(nb - k, b, ranks), priority=3,
                            deps=[pjoin], step=k)

        base_deps = [col_dep] + ([join_trail] if join_trail is not None else [])

        # ---- lookahead family (column k+1) --------------------------------
        if k + 1 < nb:
            lparent = g.add(noop, name=f"look*[{k}]", kind="lookahead",
                            cost=SPAWN_COST * (nb - k - 1), priority=2,
                            deps=base_deps, step=k)
            lchildren = [
                g.add(update_body(i, k + 1, k), name=f"upd[{i},{k + 1},{k}]",
                      kind="lookahead",
                      cost=cm.syrk(b) if i == k + 1 else cm.gemm(b),
                      priority=2, deps=[lparent], step=k,
                      fuse=_fuse(tile_gemm_sub,
                                 [(i, k + 1), (i, k), (k + 1, k)], [(i, k + 1)]))
                for i in range(k + 1, nb)
            ]
            join_look = g.add(noop, name=f"look.join[{k}]", kind="lookahead",
                              cost=0.0, priority=2, deps=lchildren, step=k)
        else:
            join_look = None

        # ---- trailing family (columns k+2..) -------------------------------
        if k + 2 < nb:
            n_tr = sum(nb - j for j in range(k + 2, nb))
            tparent = g.add(noop, name=f"trail*[{k}]", kind="compute",
                            cost=SPAWN_COST * n_tr, priority=0,
                            deps=base_deps, step=k)
            tchildren = []
            for j in range(k + 2, nb):
                for i in range(j, nb):
                    tchildren.append(
                        g.add(update_body(i, j, k), name=f"upd[{i},{j},{k}]",
                              kind="compute",
                              cost=cm.syrk(b) if i == j else cm.gemm(b),
                              priority=0, deps=[tparent], step=k,
                              fuse=_fuse(tile_gemm_sub,
                                         [(i, j), (i, k), (j, k)], [(i, j)])))
            join_trail = g.add(noop, name=f"trail.join[{k}]", kind="compute",
                               cost=0.0, priority=0, deps=tchildren, step=k)
        else:
            join_trail = None
    return g


def cholesky_graph_key(
    nb: int,
    b: int = 64,
    *,
    cost: Optional[CostModel] = None,
    ranks: int = 4,
    comm: bool = True,
):
    """Structural replay-cache key for :func:`build_cholesky_graph`.

    Computed from a body-less cost-model build (no tile store needed): the
    key ignores callables, so it is identical to the key of a numeric build
    with the same shape parameters — an iterative sweep keys its
    :class:`~repro.replay.GraphCache` lookups on this and hits the recording
    from step 1 on every later step."""
    from ..replay import graph_key
    return graph_key(build_cholesky_graph(nb, b, cost=cost, ranks=ranks, comm=comm))


def cholesky_extract(store: TileStore) -> jnp.ndarray:
    """Assemble L (zeroing the strictly-upper tiles)."""
    return jnp.tril(store.assemble())


def reference_cholesky(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.cholesky(a)


def random_spd(n: int, seed: int = 0, dtype=jnp.float64) -> jnp.ndarray:
    import numpy as np

    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n))
    a = m @ m.T + n * np.eye(n)
    return jnp.asarray(a, dtype=dtype)
