"""Dynamic-vs-replay overhead benchmark (the record-and-replay subsystem).

Two measurements over the nb=8 tiled-Cholesky graph shape, across worker
counts and victim policies:

* ``sched_overhead`` — task bodies are no-ops, so per-iteration wall clock
  *is* scheduling overhead.  Replay walks preallocated run lists with
  per-task locks; the dynamic runtime pays queues + global indegree lock +
  victim selection.  Replay must win where stealing overhead dominates
  (1-2 workers).
* ``numeric`` — real tile bodies (JAX CPU ops), driven through a
  :class:`~repro.replay.GraphCache` exactly like an iterative sweep:
  iteration 1 records, every later iteration replays.

Emits CSV rows (benchmarks.common schema) and writes ``BENCH_replay.json``
(list of the same row dicts + meta) for machine consumption.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import Runtime
from repro.linalg import build_cholesky_graph, cholesky_extract, random_spd, to_tiles
from repro.replay import GraphCache, ReplayExecutor

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
NB = 4 if SMOKE else 8
B = 32 if SMOKE else 64
WORKERS = (1, 2) if SMOKE else (1, 2, 4)
POLICIES = ("hybrid",) if SMOKE else ("hybrid", "history")
ITERS = 8 if SMOKE else 30
JSON_PATH = os.environ.get("BENCH_REPLAY_JSON", "BENCH_replay.json")


def _noop_graph() -> object:
    g = build_cholesky_graph(NB, B)
    for t in g.tasks:
        t.fn = lambda ctx: None
    return g


def bench_overhead(workers: int, policy: str, iters: int = ITERS,
                   repeats: int = 3) -> Dict:
    """Best-of-``repeats`` mean per-iteration wall clock, noop bodies."""
    dyn_best = rep_best = float("inf")
    rt = Runtime(workers, policy=policy)
    with rt:
        rt.run(_noop_graph())                         # warm the pool
        for _ in range(repeats):
            graphs = [_noop_graph() for _ in range(iters)]
            t0 = time.perf_counter()
            for g in graphs:
                rt.run(g)
            dyn_best = min(dyn_best, (time.perf_counter() - t0) / iters)
        rt.run(_noop_graph(), record=True)
        rec = rt.last_recording
    ex = ReplayExecutor(rec)
    with ex:
        ex.run(_noop_graph())
        for _ in range(repeats):
            graphs = [_noop_graph() for _ in range(iters)]
            t0 = time.perf_counter()
            for g in graphs:
                ex.run(g)
            rep_best = min(rep_best, (time.perf_counter() - t0) / iters)
    return {
        "bench": "sched_overhead", "kernel": "cholesky", "nb": NB,
        "workers": workers, "policy": policy,
        "dynamic_ms": round(dyn_best * 1e3, 4),
        "replay_ms": round(rep_best * 1e3, 4),
        "speedup": round(dyn_best / rep_best, 3),
    }


def bench_numeric(workers: int, policy: str,
                  iters: int = 4 if SMOKE else 20) -> Dict:
    """Numeric sweep: iteration 1 records into a GraphCache, the rest replay
    on a persistent executor (a real sweep keeps both pools warm).  Asserts
    the replayed factorization is bit-identical to the dynamic one."""
    import numpy as np

    a = random_spd(NB * B, seed=0)
    cache = GraphCache()
    dyn_times: List[float] = []
    rep_times: List[float] = []
    # dynamic baseline: persistent runtime
    rt = Runtime(workers, policy=policy)
    with rt:
        for _ in range(iters):
            st = to_tiles(a, B)
            g = build_cholesky_graph(NB, B, store=st)
            t0 = time.perf_counter()
            rt.run(g)
            cholesky_extract(st).block_until_ready()
            dyn_times.append(time.perf_counter() - t0)
        # every iteration factors the same matrix: one reference capture
        l_dyn = np.asarray(cholesky_extract(st))
        # iteration 1 of the cached sweep: dynamic + record (best-of-3 —
        # a one-shot measurement is at the mercy of machine noise).  Timed
        # window matches the dynamic/replay loops: run + extract + sync;
        # cache serialization happens outside it.
        record_s = float("inf")
        for _ in range(3):
            st = to_tiles(a, B)
            g = build_cholesky_graph(NB, B, store=st)
            t0 = time.perf_counter()
            rt.run(g, record=True)
            cholesky_extract(st).block_until_ready()
            record_s = min(record_s, time.perf_counter() - t0)
            cache.store(rt.last_recording)
    # iterations 2..n: replay from the cache on a persistent executor
    rec = cache.lookup(g, workers, policy)
    ex = ReplayExecutor(rec)
    with ex:
        for _ in range(iters):
            st = to_tiles(a, B)
            g = build_cholesky_graph(NB, B, store=st)
            t0 = time.perf_counter()
            ex.run(g)
            cholesky_extract(st).block_until_ready()
            rep_times.append(time.perf_counter() - t0)
            identical = bool((np.asarray(cholesky_extract(st)) == l_dyn).all())
            assert identical, "replay result diverged from dynamic execution"
    dyn = min(dyn_times[1:])                 # drop the warmup iteration
    rep = min(rep_times[1:])
    return {
        "bench": "numeric", "kernel": "cholesky", "nb": NB,
        "workers": workers, "policy": policy,
        "dynamic_ms": round(dyn * 1e3, 4),
        "replay_ms": round(rep * 1e3, 4),
        "record_ms": round((record_s or 0.0) * 1e3, 4),
        "speedup": round(dyn / rep, 3),
        "identical": identical,
    }


def bench(full: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    for policy in POLICIES:
        for w in WORKERS:
            rows.append(bench_overhead(w, policy))
    if full:
        for w in WORKERS:
            rows.append(bench_numeric(w, "hybrid"))
    return rows


def write_json(rows: List[Dict], path: str = JSON_PATH) -> None:
    out = {
        "bench": "replay",
        "meta": {"nb": NB, "b": B, "workers": list(WORKERS),
                 "policies": list(POLICIES)},
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(out, fh, indent=1)


def main():
    from .common import emit
    rows = bench()
    # separate CSV blocks (the numeric rows carry an extra record_ms column)
    emit([r for r in rows if r["bench"] == "sched_overhead"])
    print()
    emit([r for r in rows if r["bench"] == "numeric"])
    write_json(rows)
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
