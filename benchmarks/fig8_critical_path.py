"""Fig. 8 analogue: critical-path composition of LU/QR — how much of the
execution is panel, communication, and other, under the baseline
(oversubscribed, history) vs our runtime (gang, hybrid)."""

from __future__ import annotations

import time
from typing import List

from .common import LU_QR_CONFIG, SIZES, build, emit, run


def bench(sizes=("small", "large")) -> List[dict]:
    rows = []
    for kernel in ("lu", "qr"):
        conf = LU_QR_CONFIG
        for size in sizes:
            nb = SIZES[size]
            g = build(kernel, nb, conf["ranks"])
            t0 = time.perf_counter()
            for label, mode, pol in (("llvm", "oversubscribe", "history"),
                                     ("hclib", "gang", "hybrid")):
                tr = run(g, conf["workers"], conf["ranks"], mode=mode, policy=pol)
                b = tr.breakdown_fraction()
                rows.append({
                    "bench": "fig8", "kernel": kernel, "size": size,
                    "runtime": label,
                    "makespan_ms": round(tr.makespan * 1e3, 2),
                    "panel_frac": round(b.get("panel", 0), 4),
                    "comm_frac": round(b.get("comm", 0), 4),
                    "compute_frac": round(b.get("compute", 0) + b.get("lookahead", 0), 4),
                    "idle_frac": round(b.get("idle", 0) + b.get("barrier", 0), 4),
                    "us_per_call": round((time.perf_counter() - t0) * 1e6, 1),
                })
    return rows


def main():
    emit(bench())


if __name__ == "__main__":
    main()
