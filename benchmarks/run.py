"""Benchmark entry point: one function per paper table/figure plus the
roofline report.  Prints CSV blocks."""

from __future__ import annotations

import time


def main() -> None:
    t0 = time.time()
    from . import (bench_replay, bench_runtime, bench_serving, fig7_lu_qr,
                   fig8_critical_path, fig9_victim, fig11_cholesky, roofline)

    print("# fig7: LU/QR gang-scheduling vs oversubscription (paper Fig. 7)")
    fig7_lu_qr.main()
    print()
    print("# fig8: critical-path composition (paper Fig. 8)")
    fig8_critical_path.main()
    print()
    print("# fig9: victim-selection policy sweep (paper Fig. 9)")
    fig9_victim.main()
    print()
    print("# fig11: distributed Cholesky + idle breakdown (paper Fig. 11)")
    fig11_cholesky.main()
    print()
    print("# wall-clock: threaded runtime overlap (real GIL-releasing ops)")
    bench_runtime.main()
    print()
    print("# replay: dynamic-vs-replay scheduling overhead (BENCH_replay.json)")
    bench_replay.main()
    print()
    print("# serving: per-request dynamic vs pooled replay (BENCH_serving.json)")
    bench_serving.main()
    print()
    print("# roofline: dry-run derived terms (EXPERIMENTS.md section Roofline)")
    roofline.main()
    print()
    print(f"# total bench time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
