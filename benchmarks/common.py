"""Shared benchmark plumbing: paper-parity configurations and CSV output."""

from __future__ import annotations

from typing import Dict, List

from repro.core import Simulator
from repro.linalg.dist import build_dist_cholesky_graph, build_dist_panel_graph
from repro.linalg.tiles import CostModel

# Paper testbed (Table 1): dual-socket 20C Skylake per node.
# LU/QR: 4 ranks x 10 threads; Cholesky: 2 ranks x 20 threads (paper §5).
LU_QR_CONFIG = dict(ranks=4, workers=40)
CHOL_CONFIG = dict(ranks=2, workers=40)
CHOL_MULTI = dict(ranks=4, workers=40)      # 4-rank (multi-node analogue)

# matrix sizes (tiles of b=192): "small" ~ 7.7k, "large" ~ 12.3k, "xl" ~ 18.4k
SIZES = {"small": 40, "large": 64, "xl": 96}
B = 192

COST = CostModel(comm_bw=3e9, comm_latency=20e-6)


def build(kernel: str, nb: int, ranks: int) -> object:
    if kernel == "cholesky":
        return build_dist_cholesky_graph(nb, B, ranks=ranks, cost=COST)
    return build_dist_panel_graph(kernel, nb, B, ranks=ranks, cost=COST)


def run(graph, workers: int, ranks: int, *, policy="hybrid", mode="gang",
        seed=0):
    sim = Simulator(workers, ranks=ranks, policy=policy, mode=mode, seed=seed)
    return sim.run(graph)


def emit(rows: List[Dict], header: bool = True) -> None:
    if header and rows:
        print(",".join(rows[0].keys()))
    for r in rows:
        print(",".join(str(v) for v in r.values()))
