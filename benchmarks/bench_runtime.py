"""Wall-clock microbenchmarks of the REAL threaded runtime (JAX CPU ops
release the GIL):

* ``wallclock_overlap`` — hybrid vs history victim selection on an
  overlap-structured graph (comm sleeps hidden behind GEMM floods);
* ``warm_reuse`` — dynamic scheduling on one persistent ``Session`` (warm
  leased workers, the unified-executor-core path) vs a fresh private-core
  ``Session`` per run (thread spawn + queue allocation per request, the
  pre-refactor ``run_graph`` cost model).  The refactor's contract: warm
  dynamic scheduling is no slower than per-run-thread scheduling at every
  worker count (``no_slower`` per row, asserted by the CI smoke job and
  gated against the committed noise floor by ``benchmarks.perf_gate``);
* ``suspend_frames`` — fan-in communication (producers feeding consumers
  over a :class:`~repro.core.Channel`) with *blocking* plain-body consumers
  (each pins a worker work-conservingly) vs *suspendable* generator-frame
  consumers (each parks worker-free).  Contract: suspendable bodies are no
  slower at equal workers (``no_slower`` per row, asserted in CI);
* ``trace_off`` — the flight recorder's off-switch cost: the same warm
  session serving the same graphs with ``trace=False`` vs ``trace=True``.
  Contract: tracing OFF is no slower than tracing ON (the no-op emitter
  adds no measurable per-event cost; ``no_slower`` per row, gated like
  ``warm_reuse``).

Every row carries ``noise`` — the observed relative spread ``(max-min)/min``
across its repeats — which the CI workflow surfaces per run: the first step
toward turning the bench-smoke job into a perf-regression gate (thresholds
need a characterized noise floor first).

Emits CSV rows (benchmarks.common schema) and ``BENCH_runtime.json``.
Env knobs: ``BENCH_SMOKE=1`` shrinks sizes for CI; ``BENCH_RUNTIME_JSON``
overrides the output path.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

import repro
from repro.core import Channel, TaskGraph

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
WORKERS = (1, 2) if SMOKE else (1, 2, 4)
FRAME_WORKERS = (2,) if SMOKE else (2, 4)
JSON_PATH = os.environ.get("BENCH_RUNTIME_JSON", "BENCH_runtime.json")


def _spread(samples: List[float]) -> float:
    """Relative spread across repeats: (max - min) / min."""
    return round((max(samples) - min(samples)) / max(min(samples), 1e-12), 4)


def overlap_graph(n_steps: int = 6, n_children: int = 8, gemm: int = 384,
                  comm_s: float = 0.03) -> TaskGraph:
    """Cholesky-shaped: per step, a comm task (sleep) on the critical path
    and a flood of GEMM children that can hide it."""
    g = TaskGraph("wall-overlap")
    rng = np.random.default_rng(0)
    mats = [np.asarray(rng.standard_normal((gemm, gemm)), np.float32)
            for _ in range(2)]

    def gemm_task(ctx):
        return float(np.linalg.norm(mats[0] @ mats[1]))

    def comm_task(ctx):
        time.sleep(comm_s)

    prev_comm = None
    prev_join = None
    for k in range(n_steps):
        pdeps = [t for t in (prev_comm,) if t is not None]
        panel = g.add(gemm_task, name=f"panel[{k}]", kind="panel", deps=pdeps)
        comm = g.add(comm_task, name=f"bcast[{k}]", kind="comm", deps=[panel])
        parent_deps = [comm] + ([prev_join] if prev_join is not None else [])
        parent = g.add(lambda ctx: None, name=f"trail*[{k}]", deps=parent_deps)
        children = [g.add(gemm_task, name=f"tr[{k}.{j}]", deps=[parent])
                    for j in range(n_children)]
        prev_join = g.add(lambda ctx: None, name=f"join[{k}]", deps=children)
        prev_comm = comm
    return g


def bench(workers: int = 4, repeats: int = 3) -> List[dict]:
    steps, children, gemm = (3, 4, 128) if SMOKE else (6, 8, 384)
    comm_s = 0.01 if SMOKE else 0.03
    rows = []
    for policy in ("history", "hybrid"):
        times = []
        for r in range(repeats):
            g = overlap_graph(steps, children, gemm, comm_s)
            with repro.Session(workers, policy=policy, seed=r) as session:
                t0 = time.perf_counter()
                session.run(g, timeout=120.0)
                times.append(time.perf_counter() - t0)
        best = min(times)
        rows.append({
            "bench": "wallclock_overlap", "policy": policy,
            "workers": workers,
            "best_s": round(best, 3),
            "us_per_call": round(best * 1e6, 1),
            "noise": _spread(times),
        })
    return rows


def reuse_graph(n_tasks: int = 48) -> TaskGraph:
    """Small mixed-fanout graph of trivial bodies: per-run scheduling and
    construction overhead dominate, which is exactly what warm reuse
    eliminates."""
    g = TaskGraph("reuse")
    root = g.add(lambda ctx: 0, name="root")
    mids = [g.add(lambda ctx, i=i: i, deps=[root], name=f"m{i}")
            for i in range(n_tasks)]
    g.add(lambda ctx: sum(ctx.dep_results()), deps=mids, name="join")
    return g


def bench_reuse(workers: int, iters: int = 10, repeats: int = 5) -> Dict:
    """Best-of-``repeats`` mean per-run wall clock: a fresh private-core
    Session per run (per-run thread spawn — what every pre-refactor
    ``run_graph`` call paid) vs one persistent Session serving every run
    on warm leased workers."""
    graphs = [reuse_graph() for _ in range(iters)]
    with repro.Session(workers) as s:
        s.run(graphs[0])                              # warm imports/JIT paths
    fresh_times: List[float] = []
    warm_times: List[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for g in graphs:
            with repro.Session(workers, shared_cores=False) as session:
                session.run(g)
        fresh_times.append((time.perf_counter() - t0) / iters)
    with repro.Session(workers) as session:
        session.run(graphs[0])                        # spawn outside the clock
        for _ in range(repeats):
            t0 = time.perf_counter()
            for g in graphs:
                session.run(g)
            warm_times.append((time.perf_counter() - t0) / iters)
    fresh_best, warm_best = min(fresh_times), min(warm_times)
    return {
        "bench": "warm_reuse", "workers": workers,
        "fresh_ms": round(fresh_best * 1e3, 4),
        "warm_ms": round(warm_best * 1e3, 4),
        "speedup": round(fresh_best / warm_best, 3),
        # generous noise headroom: the claim is "no slower", not "faster"
        "no_slower": bool(warm_best <= fresh_best * 1.25),
        "noise": _spread(warm_times),
    }


def bench_trace_off(workers: int, iters: int = 10, repeats: int = 5) -> Dict:
    """Tracing-off vs tracing-on on warm sessions serving the same graphs.
    The observability contract is that the OFF path costs nothing: hot
    loops call the module-level no-op emitter (one attribute call, zero
    allocation), so ``off_ms <= on_ms * headroom`` must hold."""
    graphs = [reuse_graph() for _ in range(iters)]
    times: Dict[bool, List[float]] = {False: [], True: []}
    for traced in (False, True):
        with repro.Session(workers, trace=traced) as session:
            session.run(graphs[0])                    # spawn outside the clock
            for _ in range(repeats):
                t0 = time.perf_counter()
                for g in graphs:
                    session.run(g)
                times[traced].append((time.perf_counter() - t0) / iters)
    off_best, on_best = min(times[False]), min(times[True])
    return {
        "bench": "trace_off", "workers": workers,
        "off_ms": round(off_best * 1e3, 4),
        "on_ms": round(on_best * 1e3, 4),
        "overhead": round(on_best / off_best, 3),
        # the gated claim: the off-switch is free (off is no slower than
        # on, with the same noise headroom the other rows use)
        "no_slower": bool(off_best <= on_best * 1.25),
        "noise": _spread(times[False]),
    }


def frames_graph(n_pairs: int, use_frames: bool, work_s: float) -> TaskGraph:
    """Fan-in communication: ``n_pairs`` consumers each receive one token
    from a channel fed by ``n_pairs`` independent producers (each doing
    ``work_s`` of off-GIL 'compute').  Blocking consumers pin their worker
    at ``ctx.recv`` (work-conservingly); suspendable consumers park."""
    g = TaskGraph("suspend" if use_frames else "blocking")
    ch = Channel("bench.tokens")
    for i in range(n_pairs):
        if use_frames:
            def body(ctx, i=i):
                v = yield ctx.recv(ch)
                return v
        else:
            def body(ctx, i=i):
                return ctx.recv(ch)
        g.add(body, name=f"cons{i}")
    for i in range(n_pairs):
        def prod(ctx, i=i):
            time.sleep(work_s)
            ch.send(i)
        g.add(prod, name=f"prod{i}")
    return g


def bench_frames(workers: int, repeats: int = 3) -> Dict:
    """Blocking-body vs suspendable-body throughput on the same fan-in
    graph.  Contract: suspendable is no slower at equal workers."""
    n_pairs = 6 if SMOKE else 12
    work_s = 0.001 if SMOKE else 0.002
    samples: Dict[str, List[float]] = {"blocking": [], "suspend": []}
    with repro.Session(workers) as warm:
        warm.run(frames_graph(n_pairs, True, work_s))         # warm paths
    for _ in range(repeats):
        for mode in ("blocking", "suspend"):
            g = frames_graph(n_pairs, mode == "suspend", work_s)
            # per-request session, spawn included in the timed window —
            # the serving-loop cost model this row has always measured
            t0 = time.perf_counter()
            with repro.Session(workers, shared_cores=False) as session:
                session.run(g, timeout=120.0)
            samples[mode].append(time.perf_counter() - t0)
    blocking_best = min(samples["blocking"])
    suspend_best = min(samples["suspend"])
    return {
        "bench": "suspend_frames", "workers": workers, "pairs": n_pairs,
        "blocking_ms": round(blocking_best * 1e3, 3),
        "suspend_ms": round(suspend_best * 1e3, 3),
        "speedup": round(blocking_best / suspend_best, 3),
        "no_slower": bool(suspend_best <= blocking_best * 1.25),
        "noise": _spread(samples["suspend"]),
    }


def write_json(rows: List[Dict], path: str = JSON_PATH) -> None:
    out = {
        "bench": "runtime",
        "meta": {"workers": list(WORKERS), "frame_workers": list(FRAME_WORKERS),
                 "smoke": SMOKE},
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(out, fh, indent=1)


def main():
    from .common import emit
    overlap_rows = bench(workers=2 if SMOKE else 4)
    emit(overlap_rows)
    print()
    reuse_rows = [bench_reuse(w) for w in WORKERS]
    emit(reuse_rows)
    print()
    trace_rows = [bench_trace_off(w) for w in WORKERS]
    emit(trace_rows)
    print()
    frame_rows = [bench_frames(w) for w in FRAME_WORKERS]
    emit(frame_rows)
    write_json(overlap_rows + reuse_rows + trace_rows + frame_rows)
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
