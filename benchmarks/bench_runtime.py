"""Wall-clock microbenchmarks of the REAL threaded runtime (JAX CPU ops
release the GIL): hybrid vs history victim selection on an
overlap-structured graph, and gang vs non-gang panel regions."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import ParallelSpec, TaskGraph, run_graph


def overlap_graph(n_steps: int = 6, n_children: int = 8, gemm: int = 384,
                  comm_s: float = 0.03) -> TaskGraph:
    """Cholesky-shaped: per step, a comm task (sleep) on the critical path
    and a flood of GEMM children that can hide it."""
    g = TaskGraph("wall-overlap")
    rng = np.random.default_rng(0)
    mats = [np.asarray(rng.standard_normal((gemm, gemm)), np.float32)
            for _ in range(2)]

    def gemm_task(ctx):
        return float(np.linalg.norm(mats[0] @ mats[1]))

    def comm_task(ctx):
        time.sleep(comm_s)

    prev_comm = None
    prev_join = None
    for k in range(n_steps):
        pdeps = [t for t in (prev_comm,) if t is not None]
        panel = g.add(gemm_task, name=f"panel[{k}]", kind="panel", deps=pdeps)
        comm = g.add(comm_task, name=f"bcast[{k}]", kind="comm", deps=[panel])
        parent_deps = [comm] + ([prev_join] if prev_join is not None else [])
        parent = g.add(lambda ctx: None, name=f"trail*[{k}]", deps=parent_deps)
        children = [g.add(gemm_task, name=f"tr[{k}.{j}]", deps=[parent])
                    for j in range(n_children)]
        prev_join = g.add(lambda ctx: None, name=f"join[{k}]", deps=children)
        prev_comm = comm
    return g


def bench(workers: int = 4, repeats: int = 3) -> List[dict]:
    rows = []
    for policy in ("history", "hybrid"):
        times = []
        for r in range(repeats):
            g = overlap_graph()
            t0 = time.perf_counter()
            run_graph(g, workers, policy=policy, seed=r, timeout=120.0)
            times.append(time.perf_counter() - t0)
        best = min(times)
        rows.append({
            "bench": "wallclock_overlap", "policy": policy,
            "workers": workers,
            "best_s": round(best, 3),
            "us_per_call": round(best * 1e6, 1),
        })
    return rows


def main():
    from .common import emit
    emit(bench())


if __name__ == "__main__":
    main()
