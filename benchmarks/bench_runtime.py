"""Wall-clock microbenchmarks of the REAL threaded runtime (JAX CPU ops
release the GIL):

* ``wallclock_overlap`` — hybrid vs history victim selection on an
  overlap-structured graph (comm sleeps hidden behind GEMM floods);
* ``warm_reuse`` — dynamic scheduling on one persistent ``Session`` (warm
  leased workers, the unified-executor-core path) vs a fresh private-core
  ``Session`` per run (thread spawn + queue allocation per request, the
  pre-refactor ``run_graph`` cost model).  The refactor's contract: warm
  dynamic scheduling is no slower than per-run-thread scheduling at every
  worker count (``no_slower`` per row, asserted by the CI smoke job and
  gated against the committed noise floor by ``benchmarks.perf_gate``);
* ``suspend_frames`` — fan-in communication (producers feeding consumers
  over a :class:`~repro.core.Channel`) with *blocking* plain-body consumers
  (each pins a worker work-conservingly) vs *suspendable* generator-frame
  consumers (each parks worker-free).  Contract: suspendable bodies are no
  slower at equal workers (``no_slower`` per row, asserted in CI);
* ``trace_off`` — the flight recorder's off-switch cost: the same warm
  session serving the same graphs with ``trace=False`` vs ``trace=True``.
  Contract: tracing OFF is no slower than tracing ON (the no-op emitter
  adds no measurable per-event cost; ``no_slower`` per row, gated like
  ``warm_reuse``);
* ``victim_frames`` — stats-driven frame-aware victim selection
  (``frame_hybrid``, fed per-run trace metrics through
  ``VictimPolicy.observe``) vs the paper hybrid on a skewed fan-in of
  suspendable frames.  Contract: frame_hybrid is no slower;
* ``compiled_linalg`` — a Cholesky sweep served by the ``compiled``
  scheduler (recordings lowered to fused jitted serial programs) vs
  ``replay`` and ``dynamic`` on the same warm substrate, with the
  driver-measured ``dispatch_overhead_fraction`` against replay's traced
  equivalent.  Contract: compiled is no slower than replay;
* ``async_overlap`` — ``Session.submit`` (async: the client builds request
  ``i+1`` — data prep + graph construction — while request ``i`` executes)
  vs the blocking build-then-run loop, same graphs, same warm session.
  Contract: pipelined submission is no slower than the serial loop;
* ``resource_contention`` — a shared-accumulator workload (skewed computes
  each feeding an update of ONE accumulator) serialized two ways: a chain
  of dependency *edges* between the updates (pins an order nobody needs)
  vs one exclusive :class:`~repro.resources.Resource` shared by the
  updates with **no cross-edges** (the arbiter serializes them in finish
  order).  Contract: the resource variant is no slower than the edge
  variant — conflicts-without-dependencies never lose to fake edges.

Every row carries ``noise`` — the observed relative spread ``(max-min)/min``
across its repeats — which the CI workflow surfaces per run: the first step
toward turning the bench-smoke job into a perf-regression gate (thresholds
need a characterized noise floor first).

Emits CSV rows (benchmarks.common schema) and ``BENCH_runtime.json``.
Env knobs: ``BENCH_SMOKE=1`` shrinks sizes for CI; ``BENCH_RUNTIME_JSON``
overrides the output path.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

import repro
from repro.core import Channel, TaskGraph
from repro.resources import Resource

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
WORKERS = (1, 2) if SMOKE else (1, 2, 4)
FRAME_WORKERS = (2,) if SMOKE else (2, 4)
JSON_PATH = os.environ.get("BENCH_RUNTIME_JSON", "BENCH_runtime.json")


def _spread(samples: List[float]) -> float:
    """Relative spread across repeats: (max - min) / min."""
    return round((max(samples) - min(samples)) / max(min(samples), 1e-12), 4)


def overlap_graph(n_steps: int = 6, n_children: int = 8, gemm: int = 384,
                  comm_s: float = 0.03) -> TaskGraph:
    """Cholesky-shaped: per step, a comm task (sleep) on the critical path
    and a flood of GEMM children that can hide it."""
    g = TaskGraph("wall-overlap")
    rng = np.random.default_rng(0)
    mats = [np.asarray(rng.standard_normal((gemm, gemm)), np.float32)
            for _ in range(2)]

    def gemm_task(ctx):
        return float(np.linalg.norm(mats[0] @ mats[1]))

    def comm_task(ctx):
        time.sleep(comm_s)

    prev_comm = None
    prev_join = None
    for k in range(n_steps):
        pdeps = [t for t in (prev_comm,) if t is not None]
        panel = g.add(gemm_task, name=f"panel[{k}]", kind="panel", deps=pdeps)
        comm = g.add(comm_task, name=f"bcast[{k}]", kind="comm", deps=[panel])
        parent_deps = [comm] + ([prev_join] if prev_join is not None else [])
        parent = g.add(lambda ctx: None, name=f"trail*[{k}]", deps=parent_deps)
        children = [g.add(gemm_task, name=f"tr[{k}.{j}]", deps=[parent])
                    for j in range(n_children)]
        prev_join = g.add(lambda ctx: None, name=f"join[{k}]", deps=children)
        prev_comm = comm
    return g


def bench(workers: int = 4, repeats: int = 3) -> List[dict]:
    steps, children, gemm = (3, 4, 128) if SMOKE else (6, 8, 384)
    comm_s = 0.01 if SMOKE else 0.03
    rows = []
    for policy in ("history", "hybrid"):
        times = []
        for r in range(repeats):
            g = overlap_graph(steps, children, gemm, comm_s)
            with repro.Session(workers, policy=policy, seed=r) as session:
                t0 = time.perf_counter()
                session.run(g, timeout=120.0)
                times.append(time.perf_counter() - t0)
        best = min(times)
        rows.append({
            "bench": "wallclock_overlap", "policy": policy,
            "workers": workers,
            "best_s": round(best, 3),
            "us_per_call": round(best * 1e6, 1),
            "noise": _spread(times),
        })
    return rows


def reuse_graph(n_tasks: int = 48) -> TaskGraph:
    """Small mixed-fanout graph of trivial bodies: per-run scheduling and
    construction overhead dominate, which is exactly what warm reuse
    eliminates."""
    g = TaskGraph("reuse")
    root = g.add(lambda ctx: 0, name="root")
    mids = [g.add(lambda ctx, i=i: i, deps=[root], name=f"m{i}")
            for i in range(n_tasks)]
    g.add(lambda ctx: sum(ctx.dep_results()), deps=mids, name="join")
    return g


def bench_reuse(workers: int, iters: int = 10, repeats: int = 5) -> Dict:
    """Best-of-``repeats`` mean per-run wall clock: a fresh private-core
    Session per run (per-run thread spawn — what every pre-refactor
    ``run_graph`` call paid) vs one persistent Session serving every run
    on warm leased workers."""
    graphs = [reuse_graph() for _ in range(iters)]
    with repro.Session(workers) as s:
        s.run(graphs[0])                              # warm imports/JIT paths
    fresh_times: List[float] = []
    warm_times: List[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for g in graphs:
            with repro.Session(workers, shared_cores=False) as session:
                session.run(g)
        fresh_times.append((time.perf_counter() - t0) / iters)
    with repro.Session(workers) as session:
        session.run(graphs[0])                        # spawn outside the clock
        for _ in range(repeats):
            t0 = time.perf_counter()
            for g in graphs:
                session.run(g)
            warm_times.append((time.perf_counter() - t0) / iters)
    fresh_best, warm_best = min(fresh_times), min(warm_times)
    return {
        "bench": "warm_reuse", "workers": workers,
        "fresh_ms": round(fresh_best * 1e3, 4),
        "warm_ms": round(warm_best * 1e3, 4),
        "speedup": round(fresh_best / warm_best, 3),
        # generous noise headroom: the claim is "no slower", not "faster"
        "no_slower": bool(warm_best <= fresh_best * 1.25),
        "noise": _spread(warm_times),
    }


def bench_trace_off(workers: int, iters: int = 10, repeats: int = 5) -> Dict:
    """Tracing-off vs tracing-on on warm sessions serving the same graphs.
    The observability contract is that the OFF path costs nothing: hot
    loops call the module-level no-op emitter (one attribute call, zero
    allocation), so ``off_ms <= on_ms * headroom`` must hold."""
    graphs = [reuse_graph() for _ in range(iters)]
    times: Dict[bool, List[float]] = {False: [], True: []}
    for traced in (False, True):
        with repro.Session(workers, trace=traced) as session:
            session.run(graphs[0])                    # spawn outside the clock
            for _ in range(repeats):
                t0 = time.perf_counter()
                for g in graphs:
                    session.run(g)
                times[traced].append((time.perf_counter() - t0) / iters)
    off_best, on_best = min(times[False]), min(times[True])
    return {
        "bench": "trace_off", "workers": workers,
        "off_ms": round(off_best * 1e3, 4),
        "on_ms": round(on_best * 1e3, 4),
        "overhead": round(on_best / off_best, 3),
        # the gated claim: the off-switch is free (off is no slower than
        # on, with the same noise headroom the other rows use)
        "no_slower": bool(off_best <= on_best * 1.25),
        "noise": _spread(times[False]),
    }


def frames_graph(n_pairs: int, use_frames: bool, work_s: float) -> TaskGraph:
    """Fan-in communication: ``n_pairs`` consumers each receive one token
    from a channel fed by ``n_pairs`` independent producers (each doing
    ``work_s`` of off-GIL 'compute').  Blocking consumers pin their worker
    at ``ctx.recv`` (work-conservingly); suspendable consumers park."""
    g = TaskGraph("suspend" if use_frames else "blocking")
    ch = Channel("bench.tokens")
    for i in range(n_pairs):
        if use_frames:
            def body(ctx, i=i):
                v = yield ctx.recv(ch)
                return v
        else:
            def body(ctx, i=i):
                return ctx.recv(ch)
        g.add(body, name=f"cons{i}")
    for i in range(n_pairs):
        def prod(ctx, i=i):
            time.sleep(work_s)
            ch.send(i)
        g.add(prod, name=f"prod{i}")
    return g


def bench_frames(workers: int, repeats: int = 3) -> Dict:
    """Blocking-body vs suspendable-body throughput on the same fan-in
    graph.  Contract: suspendable is no slower at equal workers."""
    n_pairs = 6 if SMOKE else 12
    work_s = 0.001 if SMOKE else 0.002
    samples: Dict[str, List[float]] = {"blocking": [], "suspend": []}
    with repro.Session(workers) as warm:
        warm.run(frames_graph(n_pairs, True, work_s))         # warm paths
    for _ in range(repeats):
        for mode in ("blocking", "suspend"):
            g = frames_graph(n_pairs, mode == "suspend", work_s)
            # per-request session, spawn included in the timed window —
            # the serving-loop cost model this row has always measured
            t0 = time.perf_counter()
            with repro.Session(workers, shared_cores=False) as session:
                session.run(g, timeout=120.0)
            samples[mode].append(time.perf_counter() - t0)
    blocking_best = min(samples["blocking"])
    suspend_best = min(samples["suspend"])
    return {
        "bench": "suspend_frames", "workers": workers, "pairs": n_pairs,
        "blocking_ms": round(blocking_best * 1e3, 3),
        "suspend_ms": round(suspend_best * 1e3, 3),
        "speedup": round(blocking_best / suspend_best, 3),
        "no_slower": bool(suspend_best <= blocking_best * 1.25),
        "noise": _spread(samples["suspend"]),
    }


def skewed_frames_graph(n_pairs: int, work_s: float) -> TaskGraph:
    """Skewed fan-in: a single root fans every producer out onto ONE
    worker's deque, the consumers are suspendable frames waiting on the
    channel — the shape where victim selection decides whether the fan-in
    drains in parallel or serializes behind the root's worker."""
    g = TaskGraph("victim-frames")
    ch = Channel("bench.skew")
    for i in range(n_pairs):
        def body(ctx, i=i):
            v = yield ctx.recv(ch)
            return v
        g.add(body, name=f"cons{i}")
    root = g.add(lambda ctx: None, name="root")
    for i in range(n_pairs):
        def prod(ctx, i=i):
            time.sleep(work_s)
            ch.send(i)
        g.add(prod, deps=[root], name=f"prod{i}")
    return g


def bench_victim_frames(workers: int, iters: int = 5, repeats: int = 3) -> Dict:
    """Frame-aware (``frame_hybrid``) vs paper-hybrid victim selection on
    the skewed fan-in graph.  One persistent *traced* session per policy:
    every run's trace metrics are fed back through ``VictimPolicy.observe``,
    so the stats-driven policy steers later runs from earlier feedback
    (``frame_resumes_by_worker`` + per-victim steal hit rates).  Contract:
    frame_hybrid is no slower than hybrid."""
    n_pairs = 8 if SMOKE else 16
    work_s = 0.001 if SMOKE else 0.002
    best: Dict[str, float] = {}
    noise = 0.0
    for policy in ("hybrid", "frame_hybrid"):
        times: List[float] = []
        with repro.Session(workers, policy=policy, trace=True) as session:
            session.run(skewed_frames_graph(n_pairs, work_s),
                        timeout=120.0)                # warm + first feedback
            for _ in range(repeats):
                t0 = time.perf_counter()
                for _ in range(iters):
                    session.run(skewed_frames_graph(n_pairs, work_s),
                                timeout=120.0)
                times.append((time.perf_counter() - t0) / iters)
        best[policy] = min(times)
        if policy == "frame_hybrid":
            noise = _spread(times)
    return {
        "bench": "victim_frames", "workers": workers, "pairs": n_pairs,
        "hybrid_ms": round(best["hybrid"] * 1e3, 3),
        "frame_ms": round(best["frame_hybrid"] * 1e3, 3),
        "speedup": round(best["hybrid"] / best["frame_hybrid"], 3),
        "no_slower": bool(best["frame_hybrid"] <= best["hybrid"] * 1.25),
        "noise": noise,
    }


def bench_compiled_linalg(workers: int, repeats: int = 4) -> Dict:
    """One Cholesky shape swept dynamic vs replay vs compiled on warm
    sessions (fresh tiles per run, identical SPD input).  The compiled
    scheduler records on the first run and serves every later run from the
    fused serial program; its driver reports
    ``dispatch_overhead_fraction`` directly (time outside kernel bodies),
    compared against the replay executor's traced equivalent.  Contract:
    compiled is no slower than replay."""
    import jax.numpy as jnp

    from repro.linalg import build_cholesky_graph, random_spd, to_tiles

    nb, b = (4, 16) if SMOKE else (6, 32)
    a = random_spd(nb * b, seed=0, dtype=jnp.float32)

    def sweep(scheduler: str):
        times: List[float] = []
        last = None
        with repro.Session(workers, scheduler=scheduler) as session:
            for _ in range(2):       # warm jit + the recording iteration
                store = to_tiles(a, b)
                session.run(build_cholesky_graph(nb, b, store=store),
                            timeout=120.0)
            for _ in range(repeats):
                store = to_tiles(a, b)
                g = build_cholesky_graph(nb, b, store=store)
                t0 = time.perf_counter()
                last = session.run(g, timeout=120.0)
                times.append(time.perf_counter() - t0)
        return times, last

    dyn_times, _ = sweep("dynamic")
    rep_times, _ = sweep("replay")
    cmp_times, cmp_report = sweep("compiled")
    # replay's overhead fraction needs the flight recorder (untimed pass);
    # the compiled driver measures its own (1 - body_s / wall_s)
    with repro.Session(workers, scheduler="replay", trace=True) as session:
        rep_traced = None
        for _ in range(3):
            store = to_tiles(a, b)
            rep_traced = session.run(build_cholesky_graph(nb, b, store=store),
                                     timeout=120.0)
    replay_overhead = (rep_traced.trace.metrics()["dispatch_overhead_fraction"]
                       if rep_traced.trace is not None else None)
    dyn_best, rep_best, cmp_best = min(dyn_times), min(rep_times), min(cmp_times)
    return {
        "bench": "compiled_linalg", "workers": workers, "nb": nb, "b": b,
        "dynamic_ms": round(dyn_best * 1e3, 3),
        "replay_ms": round(rep_best * 1e3, 3),
        "compiled_ms": round(cmp_best * 1e3, 3),
        "speedup_vs_dynamic": round(dyn_best / cmp_best, 3),
        "speedup_vs_replay": round(rep_best / cmp_best, 3),
        "compiled_overhead_fraction": round(
            float(cmp_report.stats.get("dispatch_overhead_fraction", 0.0)), 4),
        "replay_overhead_fraction": (round(float(replay_overhead), 4)
                                     if replay_overhead is not None else None),
        "segments": int(cmp_report.stats.get("segments", 0)),
        "fused_tasks": int(cmp_report.stats.get("fused_tasks", 0)),
        "no_slower": bool(cmp_best <= rep_best * 1.25),
        "noise": _spread(cmp_times),
    }


def bench_async_overlap(workers: int, iters: int = 8,
                        repeats: int = 3) -> Dict:
    """Blocking build-then-run loop vs ``Session.submit`` pipelining.

    Each request is a realistic client turn: *build* (seeded data prep +
    graph construction, GIL-bound on the caller) then *run* (sleep-bodied
    tasks — off-GIL waiting, like device execution).  The serial loop pays
    ``iters * (build + run)``; the submit loop builds request ``i+1``
    while request ``i`` executes, so builds vanish into execution time.
    Contract: pipelining is no slower (and on any box, strictly hides the
    build cost up to noise)."""
    gemm = 256 if SMOKE else 384
    sleep_s = 0.004
    n_sleep = max(2, workers)

    def build(seed: int) -> TaskGraph:
        rng = np.random.default_rng(seed)
        mats = [np.asarray(rng.standard_normal((gemm, gemm)), np.float32)
                for _ in range(4)]                    # client-side prep
        g = TaskGraph("async-overlap")
        for i in range(n_sleep):
            def body(ctx, i=i):
                time.sleep(sleep_s)
                return i
            g.add(body, name=f"io{i}")
        g.add(lambda ctx: float(np.linalg.norm(mats[0] + mats[-1])),
              name="checksum")
        return g

    serial_times: List[float] = []
    overlap_times: List[float] = []
    with repro.Session(workers) as session:
        session.run(build(0))                         # warm paths
        for _ in range(repeats):
            t0 = time.perf_counter()
            for i in range(iters):
                session.run(build(i))
            serial_times.append((time.perf_counter() - t0) / iters)
    with repro.Session(workers) as session:
        session.run(build(0))
        for _ in range(repeats):
            t0 = time.perf_counter()
            fut = session.submit(build(0))
            for i in range(1, iters):
                nxt = build(i)                        # overlaps fut's run
                fut.result(timeout=120.0)
                fut = session.submit(nxt)
            fut.result(timeout=120.0)
            overlap_times.append((time.perf_counter() - t0) / iters)
    serial_best, overlap_best = min(serial_times), min(overlap_times)
    return {
        "bench": "async_overlap", "workers": workers, "iters": iters,
        "serial_ms": round(serial_best * 1e3, 4),
        "overlap_ms": round(overlap_best * 1e3, 4),
        "speedup": round(serial_best / overlap_best, 3),
        "no_slower": bool(overlap_best <= serial_best * 1.25),
        "noise": _spread(overlap_times),
    }


def contention_graph(use_resource: bool, n_tasks: int, acc: List[int],
                     compute_s: float, write_s: float) -> TaskGraph:
    """Skewed computes each feeding an update of ONE shared accumulator.
    ``use_resource=False`` serializes the updates with a chain of edges
    (update ``i`` must wait for update ``i-1`` — and task 0's compute is
    the LONGEST, so the whole chain stalls behind it).  ``use_resource=True``
    drops the chain: the updates share one exclusive resource and the
    arbiter grants it in whatever order the computes finish — short
    computes' updates overlap the long computes still running."""
    g = TaskGraph("contend-res" if use_resource else "contend-edges")
    accumulator = Resource("accumulator") if use_resource else None
    prev = None
    for i in range(n_tasks):
        def compute(ctx, i=i):
            time.sleep(compute_s * (n_tasks - i) / n_tasks)   # 0 = longest
            return i

        def update(ctx, i=i):
            time.sleep(write_s)                # the guarded critical section
            acc.append(i)

        c = g.add(compute, name=f"compute{i}", kind="compute", cost=1.0)
        deps = [c] if use_resource else [c] + ([prev] if prev is not None
                                               else [])
        prev = g.add(update, name=f"update{i}", kind="comm", cost=0.2,
                     deps=deps,
                     uses=[accumulator] if use_resource else ())
    return g


def bench_resources(workers: int, repeats: int = 3) -> Dict:
    """Edge-serialized vs resource-serialized shared-accumulator updates on
    the same warm session.  Contract: resources are no slower than edges
    (same mutual exclusion, no fake ordering)."""
    n_tasks = 4 if SMOKE else 8
    compute_s = 0.004 if SMOKE else 0.01
    write_s = 0.001 if SMOKE else 0.002
    samples: Dict[str, List[float]] = {"edges": [], "resources": []}
    sums: Dict[str, int] = {}
    waits = acquires = 0
    with repro.Session(workers) as session:
        session.run(contention_graph(True, n_tasks, [], compute_s, write_s))
        for _ in range(repeats):
            for mode in ("edges", "resources"):
                acc: List[int] = []
                g = contention_graph(mode == "resources", n_tasks, acc,
                                     compute_s, write_s)
                t0 = time.perf_counter()
                rep = session.run(g, timeout=120.0)
                samples[mode].append(time.perf_counter() - t0)
                assert len(acc) == n_tasks
                sums[mode] = sum(acc)
                if mode == "resources":
                    waits = int(rep.stats.get("resource_waits", 0))
                    acquires = int(rep.stats.get("resource_acquires", 0))
    edges_best = min(samples["edges"])
    res_best = min(samples["resources"])
    return {
        "bench": "resource_contention", "workers": workers, "tasks": n_tasks,
        "edges_ms": round(edges_best * 1e3, 3),
        "resources_ms": round(res_best * 1e3, 3),
        "speedup": round(edges_best / res_best, 3),
        "resource_acquires": acquires,
        "resource_waits": waits,
        # same accumulator contents either way (order differs by design)
        "identical": bool(sums["edges"] == sums["resources"]),
        "no_slower": bool(res_best <= edges_best * 1.25),
        "noise": _spread(samples["resources"]),
    }


def write_json(rows: List[Dict], path: str = JSON_PATH) -> None:
    out = {
        "bench": "runtime",
        "meta": {"workers": list(WORKERS), "frame_workers": list(FRAME_WORKERS),
                 "smoke": SMOKE},
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(out, fh, indent=1)


def main():
    from .common import emit
    overlap_rows = bench(workers=2 if SMOKE else 4)
    emit(overlap_rows)
    print()
    reuse_rows = [bench_reuse(w) for w in WORKERS]
    emit(reuse_rows)
    print()
    trace_rows = [bench_trace_off(w) for w in WORKERS]
    emit(trace_rows)
    print()
    frame_rows = [bench_frames(w) for w in FRAME_WORKERS]
    emit(frame_rows)
    print()
    victim_rows = [bench_victim_frames(w) for w in FRAME_WORKERS]
    emit(victim_rows)
    print()
    compiled_rows = [bench_compiled_linalg(w) for w in FRAME_WORKERS]
    emit(compiled_rows)
    print()
    async_rows = [bench_async_overlap(w) for w in WORKERS]
    emit(async_rows)
    print()
    resource_rows = [bench_resources(w) for w in FRAME_WORKERS]
    emit(resource_rows)
    write_json(overlap_rows + reuse_rows + trace_rows + frame_rows
               + victim_rows + compiled_rows + async_rows + resource_rows)
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
