"""Fig. 7 analogue: LU/QR with gang-scheduled panels (HClib OMP) vs the
oversubscribed nested-parallel baseline (LLVM OMP), across matrix sizes —
the paper's headline LU/QR result (up to 13.82% / 15.2%)."""

from __future__ import annotations

import time
from typing import List

from .common import LU_QR_CONFIG, SIZES, build, emit, run


def bench(sizes=("small", "large"), seeds=(0, 1, 2)) -> List[dict]:
    rows = []
    for kernel in ("lu", "qr"):
        conf = LU_QR_CONFIG
        for size in sizes:
            nb = SIZES[size]
            g = build(kernel, nb, conf["ranks"])
            res = {}
            t0 = time.perf_counter()
            for mode in ("oversubscribe", "gang"):
                ms = [run(g, conf["workers"], conf["ranks"], mode=mode,
                          policy="hybrid", seed=s).makespan for s in seeds]
                res[mode] = sum(ms) / len(ms)
            gain = 100 * (res["oversubscribe"] - res["gang"]) / res["oversubscribe"]
            rows.append({
                "bench": "fig7", "kernel": kernel, "size": size,
                "oversub_ms": round(res["oversubscribe"] * 1e3, 2),
                "gang_ms": round(res["gang"] * 1e3, 2),
                "gang_gain_pct": round(gain, 2),
                "us_per_call": round((time.perf_counter() - t0) * 1e6 / (2 * len(seeds)), 1),
            })
    return rows


def main():
    emit(bench())


if __name__ == "__main__":
    main()
