"""Roofline terms per (arch x shape x mesh) cell from the dry-run artifacts.

    compute term    = FLOPs / (chips x 197e12)          [bf16 peak/chip]
    memory term     = HBM bytes / (chips x 819e9)
    collective term = collective bytes / (chips x ~50e9 per link)

FLOPs use the analytic model (XLA's cost_analysis counts while bodies once —
see launch/hlo_analysis; the HLO-derived, trip-count-corrected dot FLOPs are
reported alongside as `hlo_dot_flops` for the useful-compute ratio).
Collective bytes are trip-count-corrected from the compiled HLO (per
participant) with per-kind ICI factors (all-reduce moves ~2x its payload).

All terms are per-device (the SPMD program is per-device); the bottleneck is
the largest term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link (~45 GB/s usable per direction)

# effective wire multiplier per collective kind (ring algorithms)
_KIND_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def analytic_flops(rec: Dict) -> float:
    """Per-device useful FLOPs for the cell (6ND train / 2ND inference +
    attention terms), from the config metadata stored in the record."""
    meta = rec["cell_meta"]
    n_active = rec["params_active"]
    seq, batch = meta["seq_len"], meta["global_batch"]
    kind = meta["kind"]
    n_dev = rec["n_devices"]
    L = meta["n_layers"]
    H, hd = meta.get("n_heads", 0), meta.get("head_dim", 0)
    window = meta.get("window", 0) or 0
    lg = meta.get("local_global_ratio", 0)

    def attn_flops_tok(ctx_len: int) -> float:
        # per token per layer: 2 matmuls of (ctx x hd) per head, causal ~ /2
        if not H:
            return 0.0
        full = 4.0 * H * hd * ctx_len * 0.5
        if lg and window:
            # (lg local + 1 global) pattern
            local = 4.0 * H * hd * min(window, ctx_len) * 0.5
            return (lg * local + full) / (lg + 1)
        return full

    if kind == "train":
        tokens = seq * batch
        f = 6.0 * n_active * tokens + 3.0 * L * attn_flops_tok(seq) * tokens
    elif kind == "prefill":
        tokens = seq * batch
        f = 2.0 * n_active * tokens + L * attn_flops_tok(seq) * tokens
    else:  # decode: one token, full-context attention (no causal halving)
        tokens = batch
        f = 2.0 * n_active * tokens
        if H:
            att = 4.0 * H * hd * seq
            if lg and window:
                att = (lg * 4.0 * H * hd * min(window, seq) + att) / (lg + 1)
            f += L * att * tokens
    return f / n_dev


def analytic_hbm_bytes(rec: Dict) -> float:
    """Per-device HBM traffic estimate: params read (sharded) x passes +
    remat re-read + cache read/write for decode + activations once."""
    meta = rec["cell_meta"]
    kind = meta["kind"]
    n_dev = rec["n_devices"]
    param_bytes = rec["params"] * 2 / n_dev          # bf16, fully sharded
    act = meta["seq_len"] * meta["global_batch"] * meta["d_model"] * 2 / n_dev
    if kind == "train":
        # fwd + remat-fwd + bwd param reads + optimizer f32 m/v read+write
        return 3 * param_bytes * max(1, rec.get("microbatches", 1)) \
            + 3 * (rec["params"] * 4 / n_dev) + 6 * act
    if kind == "prefill":
        return param_bytes + 4 * act
    # decode: params + full KV/state cache read
    cache = rec.get("cache_bytes_per_dev", 0.0)
    return param_bytes + cache + 4 * meta["global_batch"] * meta["d_model"] * 2 / n_dev


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    flops = analytic_flops(rec)
    compute_t = flops / PEAK_FLOPS
    hbm = analytic_hbm_bytes(rec)
    memory_t = hbm / HBM_BW
    coll = rec.get("collectives_corrected") or rec["collectives"]
    coll_t = 0.0
    for kind, factor in _KIND_FACTOR.items():
        coll_t += coll.get(kind, {}).get("bytes", 0.0) * factor / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    bottleneck = max(terms, key=terms.get)
    total = max(terms.values())
    model_flops = 6.0 * rec["params_active"] * rec["cell_meta"]["seq_len"] * \
        rec["cell_meta"]["global_batch"] / n_dev
    if rec["cell_meta"]["kind"] != "train":
        model_flops /= 3.0
    hlo_flops = rec.get("hlo_dot_flops", rec.get("flops", 0.0))
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": f"{compute_t:.2e}",
        "memory_s": f"{memory_t:.2e}",
        "collective_s": f"{coll_t:.2e}",
        "bottleneck": bottleneck,
        "roofline_frac": round(compute_t / total, 3) if total else 0.0,
        "useful_ratio": round(min(10.0, flops / hlo_flops), 3) if hlo_flops else "n/a",
        "temp_gib": round(rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30, 2),
        "fits_16g": rec["memory"].get("temp_size_in_bytes", 0) < 16 * 2 ** 30,
    }


def main(out_dir: str = "results/dryrun"):
    rows: List[Dict] = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(f))
        row = roofline_row(rec)
        if row:
            rows.append(row)
    if not rows:
        print("bench,roofline,SKIPPED (no dry-run artifacts; run "
              "`python -m repro.launch.dryrun --all` first)")
        return
    from .common import emit
    emit(rows)


if __name__ == "__main__":
    main()
