"""Fig. 9 analogue: victim-selection policy sweep (history/random/hybrid) on
LU, QR, Cholesky — the paper's claim: Cholesky is highly policy-sensitive
(hybrid best), LU/QR barely move."""

from __future__ import annotations

import time
from typing import List

from .common import CHOL_CONFIG, LU_QR_CONFIG, SIZES, build, emit, run


def bench(sizes=("small", "large"), policies=("history", "random", "hybrid"),
          seeds=(0, 1, 2)) -> List[dict]:
    rows = []
    for kernel in ("cholesky", "lu", "qr"):
        conf = CHOL_CONFIG if kernel == "cholesky" else LU_QR_CONFIG
        for size in sizes:
            nb = SIZES[size]
            g = build(kernel, nb, conf["ranks"])
            base = None
            for pol in policies:
                t0 = time.perf_counter()
                ms = [run(g, conf["workers"], conf["ranks"], policy=pol,
                          seed=s).makespan for s in seeds]
                mean = sum(ms) / len(ms)
                if pol == "history":
                    base = mean
                rows.append({
                    "bench": "fig9", "kernel": kernel, "size": size,
                    "policy": pol,
                    "makespan_ms": round(mean * 1e3, 2),
                    "vs_history_pct": round(100 * (base - mean) / base, 2),
                    "us_per_call": round((time.perf_counter() - t0) * 1e6 / len(seeds), 1),
                })
    return rows


def main():
    emit(bench())


if __name__ == "__main__":
    main()
