"""Perf-regression gate over ``bench_runtime`` rows (perf-gate step two).

Step one (PR 4) made every ``bench_runtime`` row record its repeat spread
(``noise = (max-min)/min``) and surfaced the per-run table in the CI job
summary.  This module closes the loop:

* ``benchmarks/noise_baseline.json`` (committed) accumulates the observed
  spreads per row key — ``<bench>/w<workers>`` — across the last runs
  (bounded window).  Maintainers refresh it with ``--accumulate`` from
  local/CI artifacts; CI uploads a candidate updated baseline as an
  artifact so the data keeps growing without CI pushing commits.
* the **gate** checks every ``no_slower``-contract row against a threshold
  derived from the *observed noise floor* instead of the old fixed 1.25x
  headroom: a row fails when its contract ratio (``warm/fresh`` for
  ``warm_reuse``, ``suspend/blocking`` for ``suspend_frames``) exceeds
  ``1 + max(MIN_FLOOR, SAFETY * observed_max_spread)``.  A regression
  bigger than anything machine noise has ever produced fails the job; one
  inside the noise envelope passes.

Usage::

    python -m benchmarks.perf_gate BENCH_runtime.json BENCH_serving.json \
        [--baseline benchmarks/noise_baseline.json] \
        [--accumulate] [--write-baseline PATH] [--summary]

Any number of bench JSON files can be gated in one invocation; their rows
are pooled (CI gates runtime *and* serving artifacts together).  Serving
rows carry an arrival ``rate``, which becomes part of the row key, and
their contracts run throughput-wise: pooled continuous batching must be no
slower than the per-request dynamic baseline at every measured rate.

Exit code 1 on a gated regression (or malformed input); 0 otherwise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "noise_baseline.json")

#: never gate tighter than this headroom, regardless of how quiet the
#: baseline looks (a handful of lucky runs must not create a hair trigger)
MIN_FLOOR = 0.25
#: multiply the worst observed spread — the contract metric compares two
#: measurements, each carrying its own noise
SAFETY = 2.0
#: spreads kept per row key (rolling window)
WINDOW = 40

# contract rows: bench -> (numerator column, denominator column)
CONTRACTS: Dict[str, Tuple[str, str]] = {
    "warm_reuse": ("warm_ms", "fresh_ms"),
    "suspend_frames": ("suspend_ms", "blocking_ms"),
    # the flight recorder's off-switch is free: tracing-off serving must
    # be no slower than the same session tracing-on
    "trace_off": ("off_ms", "on_ms"),
    # pooled replay serving must be no slower than per-request dynamic
    # scheduling of the same decode loop...
    "serving": ("pooled_ms", "dynamic_ms"),
    # ...and under streaming traffic, continuous batching must sustain at
    # least the per-request dynamic baseline's throughput at every rate
    # (ratio is dynamic/pooled so "bigger = pooled regressed", matching
    # the other contracts' direction)
    "serving_poisson": ("dynamic_tok_s", "pooled_tok_s"),
    # compiled plans: the fused serial program must be no slower than
    # per-request dynamic dispatch of the same decode step (it exists to
    # beat it exactly where dynamic collapses, multi-worker decode)...
    "serving_compiled": ("compiled_ms", "dynamic_ms"),
    # ...and no slower than warm replay on the linalg sweep it fuses
    "compiled_linalg": ("compiled_ms", "replay_ms"),
    # stats-driven frame-aware victim selection must not regress the
    # paper's hybrid policy on the skewed fan-in shape it targets
    "victim_frames": ("frame_ms", "hybrid_ms"),
    # sharded multi-process serving must sustain at least the best
    # single-process pooled throughput at equal total worker count
    # (ratio is single/procs so "bigger = sharding regressed")
    "serving_procs": ("single_tok_s", "procs_tok_s"),
    # async Session.submit pipelining must be no slower than the same
    # graph stream awaited serially
    "async_overlap": ("overlap_ms", "serial_ms"),
    # declarative mutual exclusion (one shared resource, no cross-edges)
    # must be no slower than serializing the same updates with a chain of
    # dependency edges — conflicts-without-dependencies never lose to
    # fake ordering
    "resource_contention": ("resources_ms", "edges_ms"),
}


def row_key(row: Dict) -> str:
    key = f"{row['bench']}/w{row['workers']}"
    if "procs" in row:
        key += f"/p{row['procs']}"
    if "rate" in row:
        key += f"/r{row['rate']:g}"
    return key


def load_baseline(path: str) -> Dict:
    if not os.path.exists(path):
        return {"rows": {}, "runs": 0}
    with open(path) as fh:
        base = json.load(fh)
    base.setdefault("rows", {})
    base.setdefault("runs", 0)
    return base


def accumulate(base: Dict, rows: List[Dict]) -> Dict:
    """Fold this run's spreads into the baseline (rolling window)."""
    for row in rows:
        if "noise" not in row:
            continue
        entry = base["rows"].setdefault(row_key(row),
                                        {"spreads": [], "count": 0})
        entry["spreads"] = (entry["spreads"] + [row["noise"]])[-WINDOW:]
        entry["count"] += 1
    base["runs"] += 1
    return base


def floor_for(base: Dict, key: str) -> Tuple[float, int]:
    """(relative headroom, samples) for a row key: the worst spread ever
    observed for it (or across all keys when unseen), scaled by SAFETY and
    clamped to MIN_FLOOR."""
    entry = base["rows"].get(key)
    if entry and entry["spreads"]:
        spreads, n = entry["spreads"], entry["count"]
    else:
        spreads = [s for e in base["rows"].values() for s in e["spreads"]]
        n = 0
    worst = max(spreads) if spreads else 0.0
    return max(MIN_FLOOR, SAFETY * worst), n


def gate(rows: List[Dict], base: Dict) -> Tuple[List[str], List[str]]:
    """Returns (failures, report lines)."""
    failures: List[str] = []
    lines = ["| row | ratio | allowed | observed spreads | verdict |",
             "|---|---|---|---|---|"]
    for row in rows:
        contract = CONTRACTS.get(row["bench"])
        if contract is None:
            continue
        num, den = contract
        if not row.get(den):
            failures.append(f"{row_key(row)}: missing/zero {den}")
            continue
        ratio = row[num] / row[den]
        floor, samples = floor_for(base, row_key(row))
        allowed = 1.0 + floor
        ok = ratio <= allowed
        lines.append(
            f"| {row_key(row)} | {ratio:.3f} | <= {allowed:.3f} "
            f"| {samples} runs | {'ok' if ok else '**REGRESSION**'} |")
        if not ok:
            failures.append(
                f"{row_key(row)}: {num}/{den} = {ratio:.3f} exceeds "
                f"1 + noise floor {floor:.3f} "
                f"({samples} baseline runs)")
    return failures, lines


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", nargs="+",
                    help="bench artifact(s) to gate, e.g. "
                         "BENCH_runtime.json BENCH_serving.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--accumulate", action="store_true",
                    help="fold this run's spreads into the baseline file")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write the (possibly accumulated) baseline here "
                         "instead of in place")
    ap.add_argument("--summary", action="store_true",
                    help="append the gate table to $GITHUB_STEP_SUMMARY")
    args = ap.parse_args(argv)

    rows: List[Dict] = []
    for path in args.bench_json:
        with open(path) as fh:
            bench = json.load(fh)
        file_rows = bench.get("rows", [])
        if not file_rows:
            print(f"perf-gate: no rows in {path}", file=sys.stderr)
            return 1
        rows.extend(file_rows)
    base = load_baseline(args.baseline)
    failures, lines = gate(rows, base)

    out_path = args.write_baseline or args.baseline
    if args.accumulate:
        accumulate(base, rows)
        with open(out_path, "w") as fh:
            json.dump(base, fh, indent=1, sort_keys=True)
            fh.write("\n")
        lines.append(f"\nbaseline: {base['runs']} accumulated runs -> "
                     f"{out_path}")

    header = ("# perf gate (no_slower contracts vs observed noise floor)"
              if not failures else
              "# perf gate: REGRESSION beyond the observed noise floor")
    text = "\n".join([header] + lines)
    print(text)
    for f in failures:
        print(f"perf-gate FAIL: {f}", file=sys.stderr)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if args.summary and summary:
        with open(summary, "a") as fh:
            fh.write(text + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
