"""Serving-loop benchmark: per-request dynamic scheduling vs pooled replay.

Drives a tiny LM's decode-step task graph (repro.models.serving) exactly the
way ``examples/serve_lm.py`` does, across worker counts:

* ``dynamic`` — every request (decode step) goes through a
  ``Session(scheduler="dynamic")``: per-request dynamic scheduling on warm
  leased workers (a *tougher* baseline than the old fresh-runtime loop).
* ``pooled``  — requests go through a ``Session(scheduler="pool")`` (a
  persistent :class:`~repro.replay.ReplayPool` underneath): request 1
  records, every later request replays on warm executor threads.
* ``compiled`` (``serving_compiled`` rows) — requests go through a
  ``Session(scheduler="compiled")``: request 1 records, every later
  request runs the recording *lowered to a fused serial program*
  (:mod:`repro.compile`) on the calling thread — no worker dispatch at
  all.  Measured across worker counts **including 4 even in smoke**: the
  multi-worker dynamic collapse is the row's whole point, and the
  compiled driver's ``dispatch_overhead_fraction`` is reported next to
  the replay executor's traced equivalent.

Steady-state request latency excludes each mode's first request (compile /
record warmup).  Correctness is asserted, not eyeballed: the pooled run's
token stream must be bit-identical to the dynamic run's, and a recording
remapped across worker counts (recorded at W, replayed at W±1) must again
produce the identical stream.

Each worker count also runs one *traced* decode step (flight recorder on)
and reports its ``dispatch_overhead_fraction`` — the fraction of worker
time NOT spent in task bodies, the number behind the multi-worker serving
collapse (see README "Observability").  The last traced step is exported
as Perfetto JSON (``TRACE_serving.json``) and schema-validated.

On top of the fixed-batch loop, ``serving_poisson`` rows drive the
request-level continuous-batching front end (:mod:`repro.serving`) under
seeded Poisson streaming traffic, across arrival rates and worker counts:
per-token latency percentiles (p50/p99), time-to-first-token percentiles,
sustained tok/s, mean batch occupancy and the pool's warm-replay hit rate
per row.  The baseline is *per-request dynamic* serving — the same engine
with ``max_batch=1`` on a dynamic session (FCFS, no batching) — and the
pooled continuous-batching token streams are asserted bit-identical to it
(each request decodes on its own KV cache, so batch composition cannot
change its stream).  One loaded steady-state window of the pooled loop is
traced and exported as the Perfetto artifact.

``serving_procs`` rows shard the same stream across worker *processes*
(``ContinuousBatchingEngine(procs=N)`` over :mod:`repro.mp`) against
single-process pooled serving at equal total workers: aggregate tok/s,
p50/p99, the children's warm-hit rate (they adopt the parent-seeded
recordings from the shared on-disk cache) — token streams again asserted
bit-identical.

Emits CSV rows (benchmarks.common schema) and ``BENCH_serving.json``.
Env knobs: ``BENCH_SMOKE=1`` shrinks steps/workers for CI;
``BENCH_SERVING_JSON`` / ``BENCH_SERVING_TRACE`` override output paths.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

ARCH = os.environ.get("BENCH_SERVING_ARCH", "qwen3-14b")
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
BATCH = 4
PROMPT = 16
STEPS = 8 if SMOKE else 24
WORKERS = (1, 2) if SMOKE else (1, 2, 4)
# compiled rows always include 4 workers: the acceptance claim is that the
# fused serial program beats dynamic dispatch exactly where dynamic
# collapses (GIL-bound multi-worker decode)
COMPILED_WORKERS = WORKERS if 4 in WORKERS else WORKERS + (4,)
REMAP_FROM = 2
# continuous-batching (serving_poisson) knobs: open-loop Poisson arrivals
RATES = (60.0, 240.0) if SMOKE else (30.0, 120.0, 480.0)   # requests/s
SERVE_REQUESTS = 8 if SMOKE else 16
SERVE_BUDGET = (2, 6) if SMOKE else (3, 9)   # ragged budgets -> shape churn
SERVE_BATCH = 4                              # engine decode slots
# multi-process sharded serving (serving_procs) knobs: (procs, workers per
# child) — compared against single-process pooled at EQUAL total workers
PROCS_CONFIGS = ((2, 1),) if SMOKE else ((2, 1), (2, 2))
PROCS_REPEATS = 2 if SMOKE else 3
JSON_PATH = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")
TRACE_PATH = os.environ.get("BENCH_SERVING_TRACE", "TRACE_serving.json")


def _setup():
    import jax

    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(ARCH).reduced(n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = PROMPT + STEPS + 2
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b, None, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, None))
    return cfg, params, batch, max_len, prefill_fn, decode_fn


def _fresh_state(setup):
    from repro.models import make_decode_state

    cfg, params, batch, max_len, prefill_fn, _ = setup
    return make_decode_state(params, cfg, batch, n_shards=BATCH,
                             max_len=max_len, prefill_fn=prefill_fn)


def _decode_loop(setup, run_request) -> tuple:
    """Run STEPS decode requests; returns (tokens ndarray, per-request s)."""
    from repro.models import build_decode_graph

    decode_fn = setup[5]
    state = _fresh_state(setup)
    lat: List[float] = []
    for _ in range(STEPS):
        g = build_decode_graph(state, decode_fn)
        t0 = time.perf_counter()
        run_request(g)
        state.step_tokens.block_until_ready()
        lat.append(time.perf_counter() - t0)
    return np.asarray(state.tokens()), lat


def _steady_ms(lat: List[float]) -> float:
    # drop compile/warmup/record steps; best-of (like bench_replay) — the
    # per-request overhead delta is deterministic, the noise floor is not
    return float(np.min(lat[2:]) * 1e3)


def _decode_loop_pair(setup, run_a, run_b) -> tuple:
    """Two request streams over independent states, interleaved step by
    step so machine noise hits both measurements equally."""
    from repro.models import build_decode_graph

    decode_fn = setup[5]
    state_a, state_b = _fresh_state(setup), _fresh_state(setup)
    lat_a: List[float] = []
    lat_b: List[float] = []
    for _ in range(STEPS):
        for state, run, lat in ((state_a, run_a, lat_a),
                                (state_b, run_b, lat_b)):
            g = build_decode_graph(state, decode_fn)
            t0 = time.perf_counter()
            run(g)
            state.step_tokens.block_until_ready()
            lat.append(time.perf_counter() - t0)
    return (np.asarray(state_a.tokens()), lat_a,
            np.asarray(state_b.tokens()), lat_b)


def _traced_step(setup, workers: int):
    """One traced decode step (after one untraced compile warmup): returns
    the step's assembled :class:`~repro.obs.trace.RuntimeTrace`."""
    import repro
    from repro.models import build_decode_graph

    decode_fn = setup[5]
    state = _fresh_state(setup)
    with repro.Session(workers, trace=True) as s:
        s.run(build_decode_graph(state, decode_fn))   # jit compiles here
        report = s.run(build_decode_graph(state, decode_fn))
    return report.trace


def bench_workers(setup, workers: int) -> Dict:
    import repro

    fallback_steals = 0
    replay_serves = 0

    with repro.Session(workers) as dyn, \
            repro.Session(workers, scheduler="pool") as pooled:
        def run_pooled(g):
            nonlocal fallback_steals, replay_serves
            report = pooled.run(g)
            if report.stats.get("pool_mode") == "replay":
                replay_serves += 1
                fallback_steals += report.stats["replay_stats"].get(
                    "fallback_steals", 0)

        tok_dyn, lat_dyn, tok_pool, lat_pool = _decode_loop_pair(
            setup,
            lambda g: dyn.run(g),
            run_pooled)
        stats = next(iter(pooled.pool.describe().values()))
    identical = bool((tok_dyn == tok_pool).all())
    assert identical, f"pooled replay diverged from dynamic at {workers} workers"
    assert stats["records"] == 1 and stats["warmups"] == 1, stats
    assert stats["replays"] + stats["rerecords"] == STEPS - 2, stats
    trace = _traced_step(setup, workers)
    dyn_ms, pool_ms = _steady_ms(lat_dyn), _steady_ms(lat_pool)
    return {
        "bench": "serving", "arch": ARCH, "workers": workers, "shards": BATCH,
        "steps": STEPS,
        "dynamic_ms": round(dyn_ms, 3),
        "pooled_ms": round(pool_ms, 3),
        "speedup": round(dyn_ms / pool_ms, 3),
        "dynamic_tok_s": round(BATCH / (dyn_ms * 1e-3), 1),
        "pooled_tok_s": round(BATCH / (pool_ms * 1e-3), 1),
        "identical": identical,
        # per-serve deviation counters (PoolRun.stats["replay_stats"]) —
        # why a speedup<1 row happened, from the bench output alone
        "replay_serves": replay_serves,
        "fallback_steals": fallback_steals,
        # flight-recorder probe: fraction of worker-time outside task
        # bodies on one traced dynamic step (the collapse diagnostic)
        "dispatch_overhead_fraction": round(
            trace.metrics()["dispatch_overhead_fraction"], 3),
        "_trace": trace,
    }


def bench_compiled(setup, workers: int) -> Dict:
    """Compiled decode vs per-request dynamic at one worker count.  The
    compiled session records request 1 and serves every later request from
    the fused serial program; a timed replay pass plus a traced replay pass
    put the compiled driver's self-measured ``dispatch_overhead_fraction``
    next to the replay executor's traced equivalent."""
    import repro

    last_report = None

    with repro.Session(workers) as dyn, \
            repro.Session(workers, scheduler="compiled") as comp:
        def run_comp(g):
            nonlocal last_report
            last_report = comp.run(g)

        tok_dyn, lat_dyn, tok_comp, lat_comp = _decode_loop_pair(
            setup, lambda g: dyn.run(g), run_comp)
    identical = bool((tok_dyn == tok_comp).all())
    assert identical, f"compiled decode diverged from dynamic at {workers} workers"
    assert last_report.plan.mode == "compiled", last_report.plan
    with repro.Session(workers, scheduler="replay") as rep:
        tok_rep, lat_rep = _decode_loop(setup, lambda g: rep.run(g))
    assert bool((tok_rep == tok_dyn).all()), \
        f"replay decode diverged from dynamic at {workers} workers"
    # replay's overhead fraction needs the flight recorder — a separate
    # untimed pass so tracing never pollutes the measured latencies
    with repro.Session(workers, scheduler="replay", trace=True) as rept:
        traced: List = []
        _decode_loop(setup, lambda g: traced.append(rept.run(g)))
    replay_trace = next((r.trace for r in reversed(traced)
                         if r.trace is not None), None)
    dyn_ms, comp_ms, rep_ms = (_steady_ms(lat_dyn), _steady_ms(lat_comp),
                               _steady_ms(lat_rep))
    steady = lat_comp[2:]
    return {
        "bench": "serving_compiled", "arch": ARCH, "workers": workers,
        "shards": BATCH, "steps": STEPS,
        "dynamic_ms": round(dyn_ms, 3),
        "replay_ms": round(rep_ms, 3),
        "compiled_ms": round(comp_ms, 3),
        "speedup_vs_dynamic": round(dyn_ms / comp_ms, 3),
        "speedup_vs_replay": round(rep_ms / comp_ms, 3),
        "compiled_tok_s": round(BATCH / (comp_ms * 1e-3), 1),
        "dynamic_tok_s": round(BATCH / (dyn_ms * 1e-3), 1),
        "compiled_overhead_fraction": round(float(
            last_report.stats.get("dispatch_overhead_fraction", 0.0)), 4),
        "replay_overhead_fraction": (round(float(
            replay_trace.metrics()["dispatch_overhead_fraction"]), 4)
            if replay_trace is not None else None),
        "segments": int(last_report.stats.get("segments", 0)),
        "fused_tasks": int(last_report.stats.get("fused_tasks", 0)),
        "identical": identical,
        "noise": round((max(steady) - min(steady)) / max(min(steady), 1e-12),
                       4),
    }


def _engine_fns(setup):
    """Adapt the jitted model callables to the engine's per-request
    signatures (params closed over; prompt shapes are constant, so both
    compile once and every request reuses the traced executable)."""
    _, params, _, _, prefill_fn, decode_fn = setup
    return (lambda cache, tok: decode_fn(params, cache, tok),
            lambda prompt: prefill_fn(params, {"tokens": prompt}))


def _workload(setup, rate: float, seed: int = 0, n: int = SERVE_REQUESTS):
    from repro.serving import PoissonWorkload

    return PoissonWorkload(rate, n, seed=seed, prompt_len=PROMPT,
                           max_new_tokens=SERVE_BUDGET,
                           vocab_size=setup[0].vocab_size)


def _drive(setup, workers: int, scheduler: str, max_batch: int,
           workload, trace: bool = False):
    import repro
    from repro.serving import ContinuousBatchingEngine

    decode_fn, prefill_fn = _engine_fns(setup)
    kwargs = {"pool_kwargs": {"warmup_runs": 0}} if scheduler == "pool" else {}
    with repro.Session(workers, scheduler=scheduler, trace=trace,
                       **kwargs) as s:
        eng = ContinuousBatchingEngine(s, decode_fn, prefill_fn,
                                       max_batch=max_batch)
        eng.prime()   # graphs + structural keys built off the hot path
        return eng.run(workload.requests())


def bench_poisson(setup, rate: float, workers: int) -> Dict:
    """One arrival-rate x worker-count row: pooled continuous batching vs
    the per-request dynamic baseline over the *same* seeded stream."""
    pooled = _drive(setup, workers, "pool", SERVE_BATCH,
                    _workload(setup, rate))
    dynamic = _drive(setup, workers, "dynamic", 1, _workload(setup, rate))
    identical = pooled.tokens_by_rid() == dynamic.tokens_by_rid()
    assert identical, (f"continuous batching changed a token stream at "
                       f"rate={rate} workers={workers}")
    ps, ds = pooled.summary(), dynamic.summary()
    return {
        "bench": "serving_poisson", "arch": ARCH, "workers": workers,
        "rate": rate, "requests": SERVE_REQUESTS, "max_batch": SERVE_BATCH,
        "tokens": int(ps["tokens"]), "steps": int(ps["steps"]),
        "p50_tok_ms": ps["p50_tok_ms"], "p99_tok_ms": ps["p99_tok_ms"],
        "ttft_p50_ms": ps["ttft_p50_ms"], "ttft_p99_ms": ps["ttft_p99_ms"],
        "pooled_tok_s": ps["tok_s"], "dynamic_tok_s": ds["tok_s"],
        "speedup": round(ps["tok_s"] / ds["tok_s"], 3) if ds["tok_s"] else 0.0,
        "warm_hit_rate": ps["warm_hit_rate"],
        "occupancy": ps["occupancy"],
        "identical": identical,
    }


#: per-process memo for make_engine_fns — each serve_open re-invokes the
#: factory, and fresh lambdas would re-trace the jits every stream; the
#: memo makes repeat streams in one worker reuse the compiled executables
_ENGINE_FNS_MEMO = None


def make_engine_fns():
    """Child-process engine-fns factory (the ``fns_ref`` target for
    ``serving_procs`` rows): rebuilds the deterministic model setup inside
    the worker — same PRNGKey seeds, bit-identical params — and adapts it
    to the engine's per-request signatures.  Code ships by import
    reference; only request/token data crosses the pipe."""
    global _ENGINE_FNS_MEMO
    if _ENGINE_FNS_MEMO is None:
        _ENGINE_FNS_MEMO = _engine_fns(_setup())
    return _ENGINE_FNS_MEMO


def _wall_tok_s(report) -> float:
    """Aggregate tok/s over the drive's wall clock — the same yardstick
    for the single-process and sharded drives (per-record timestamps are
    child-local in the sharded case)."""
    return report.total_tokens / report.wall_s if report.wall_s else 0.0


def bench_procs(setup, procs: int, workers: int, rate: float) -> Dict:
    """One (procs x workers-per-child) row: sharded multi-process serving
    vs single-process pooled serving at EQUAL total workers, same seeded
    stream.  The parent seeds the shared on-disk cache first, so children
    ADOPT its recordings (warm-hit rate reported per row); one warmup
    sharded drive absorbs child-side jit compilation, then best-of
    ``PROCS_REPEATS`` measured drives."""
    import tempfile

    import repro
    from repro.replay import GraphCache
    from repro.serving import ContinuousBatchingEngine

    total = procs * workers
    # double the stream vs the other serving rows so per-stream fixed
    # costs (serve_open/close round trips) amortize out of the comparison
    n_reqs = SERVE_REQUESTS * 2
    single = _drive(setup, total, "pool", SERVE_BATCH,
                    _workload(setup, rate, n=n_reqs))
    single_tok_s = _wall_tok_s(single)

    decode_fn, prefill_fn = _engine_fns(setup)
    with tempfile.TemporaryDirectory() as cache_dir:
        # parent seeds the shipment channel at the CHILD worker count: the
        # sharded drive's children adopt these recordings from disk instead
        # of paying their own recording runs
        with repro.Session(workers, scheduler="pool",
                           cache=GraphCache(cache_dir),
                           pool_kwargs={"warmup_runs": 0}) as seeder:
            ContinuousBatchingEngine(
                seeder, decode_fn, prefill_fn,
                max_batch=SERVE_BATCH).run(
                    _workload(setup, rate, n=n_reqs).requests())
        with repro.Session(workers, scheduler="pool",
                           cache=GraphCache(cache_dir),
                           pool_kwargs={"warmup_runs": 0}, procs=procs) as s:
            def drive():
                eng = ContinuousBatchingEngine(
                    s, decode_fn, prefill_fn, max_batch=SERVE_BATCH,
                    procs=procs,
                    fns_ref="benchmarks.bench_serving:make_engine_fns")
                return eng.run(_workload(setup, rate, n=n_reqs).requests()), eng
            drive()                    # warmup: child jit + any shape gaps
            samples = [drive() for _ in range(PROCS_REPEATS)]

    toks = [_wall_tok_s(rep) for rep, _ in samples]
    best, eng = samples[max(range(len(toks)), key=toks.__getitem__)]
    identical = best.tokens_by_rid() == single.tokens_by_rid()
    assert identical, (f"sharding changed a token stream at procs={procs} "
                       f"workers={workers} rate={rate}")
    assert eng.mp_stats["dead"] == [] and eng.mp_stats["fallback"] == 0, \
        eng.mp_stats
    procs_tok_s = max(toks)
    ms = best.summary()
    # a box with fewer cores than worker processes can only timeslice the
    # children — sharding cannot win there, so the gate relaxes to "not
    # catastrophically slower"; with real parallelism available it keeps
    # the same 1.25 noise headroom every other gated row uses
    cores = (len(os.sched_getaffinity(0))
             if hasattr(os, "sched_getaffinity") else (os.cpu_count() or 1))
    headroom = 1.25 if cores >= procs else 1.6
    return {
        "bench": "serving_procs", "arch": ARCH, "procs": procs,
        "workers": workers, "total_workers": total, "rate": rate,
        "requests": n_reqs, "max_batch": SERVE_BATCH,
        "procs_tok_s": round(procs_tok_s, 1),
        "single_tok_s": round(single_tok_s, 1),
        "speedup": (round(procs_tok_s / single_tok_s, 3)
                    if single_tok_s else 0.0),
        "p50_tok_ms": ms["p50_tok_ms"], "p99_tok_ms": ms["p99_tok_ms"],
        "warm_hit_rate": ms["warm_hit_rate"],
        "identical": identical,
        "cores": cores,
        "no_slower": bool(single_tok_s <= procs_tok_s * headroom),
        "noise": round((max(toks) - min(toks)) / max(min(toks), 1e-12), 4),
    }


def _traced_window(setup, workers: int):
    """A short loaded burst with the flight recorder on — a separate drive
    so tracing overhead never pollutes the measured rows.  The engine keeps
    the most heavily loaded step's trace (the steady-state window)."""
    report = _drive(setup, workers, "pool", SERVE_BATCH,
                    _workload(setup, RATES[-1], seed=1,
                              n=min(SERVE_REQUESTS, 6)),
                    trace=True)
    return report.trace


def bench_remap(setup, src_workers: int, dst_workers: int,
                reference: np.ndarray) -> Dict:
    """Record at ``src_workers``, remap, replay the whole decode loop at
    ``dst_workers`` — token stream must match the dynamic reference."""
    import repro
    from repro.replay import GraphCache, remap_recording

    cache = GraphCache()
    reports: List = []
    with repro.Session(src_workers, scheduler="pool", cache=cache) as src:
        _decode_loop(setup, lambda g: reports.append(src.run(g)))
    # the recording rides the RunReport — no pool.last_recording reach-in
    rec = next(iter(cache.candidates(
        reports[-1].recording.digest).values()))
    remapped = remap_recording(rec, dst_workers)
    cache.store(remapped)

    # a replica pool at the new worker count adopts the shipped recording:
    # no dynamic recording run happens (records stays 0)
    with repro.Session(dst_workers, scheduler="pool", cache=cache,
                       allow_remap=False) as replica:
        tok, lat = _decode_loop(setup, lambda g: replica.run(g))
        stats = next(iter(replica.pool.describe().values()))
    identical = bool((tok == reference).all())
    assert identical, f"remapped replay {src_workers}->{dst_workers} diverged"
    assert stats["records"] == 0, stats
    return {
        "bench": "serving_remap", "arch": ARCH,
        "from_workers": src_workers, "to_workers": dst_workers,
        "steps": STEPS, "pooled_ms": round(_steady_ms(lat), 3),
        "identical": identical,
    }


def bench() -> List[Dict]:
    import repro

    setup = _setup()
    rows = [bench_workers(setup, w) for w in WORKERS]
    rows += [bench_compiled(setup, w) for w in COMPILED_WORKERS]
    with repro.Session(REMAP_FROM) as session:
        reference, _ = _decode_loop(setup, lambda g: session.run(g))
    for dst in (REMAP_FROM - 1, REMAP_FROM + 1):
        rows.append(bench_remap(setup, REMAP_FROM, dst, reference))
    for rate in RATES:
        for w in WORKERS:
            rows.append(bench_poisson(setup, rate, w))
    # attach the continuous-batching steady-state trace to its widest row
    rows[-1]["_trace"] = _traced_window(setup, max(WORKERS))
    for procs, w in PROCS_CONFIGS:
        rows.append(bench_procs(setup, procs, w, RATES[-1]))
    return rows


def write_json(rows: List[Dict], path: str = JSON_PATH) -> None:
    out = {
        "bench": "serving",
        "meta": {"arch": ARCH, "batch": BATCH, "prompt": PROMPT,
                 "steps": STEPS, "workers": list(WORKERS),
                 "compiled_workers": list(COMPILED_WORKERS), "smoke": SMOKE,
                 "rates": list(RATES), "serve_requests": SERVE_REQUESTS,
                 "serve_budget": list(SERVE_BUDGET),
                 "serve_batch": SERVE_BATCH,
                 "procs_configs": [list(c) for c in PROCS_CONFIGS],
                 "procs_repeats": PROCS_REPEATS},
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(out, fh, indent=1)


def write_trace_json(rows: List[Dict], path: str = TRACE_PATH) -> None:
    """Export the widest worker-count traced step as Perfetto JSON and
    schema-validate it (the CI bench-smoke artifact)."""
    from repro.obs import validate_trace_json, write_trace

    traced = [r for r in rows if r.get("_trace") is not None]
    if not traced:
        return
    # prefer the continuous-batching steady-state window, widest worker set
    row = max(traced,
              key=lambda r: (r["bench"] == "serving_poisson", r["workers"]))
    write_trace(row.pop("_trace"), path,
                extra={"workers": row["workers"], "arch": ARCH})
    for r in traced:
        r.pop("_trace", None)
    info = validate_trace_json(path)
    print(f"# wrote {path} ({info['slices']} slices, {info['flows']} flows, "
          f"schema {info['schema']})")


def main():
    from .common import emit

    rows = bench()
    write_trace_json(rows)
    emit([r for r in rows if r["bench"] == "serving"])
    print()
    emit([r for r in rows if r["bench"] == "serving_compiled"])
    print()
    emit([r for r in rows if r["bench"] == "serving_remap"])
    print()
    emit([r for r in rows if r["bench"] == "serving_poisson"])
    print()
    emit([r for r in rows if r["bench"] == "serving_procs"])
    write_json(rows)
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
