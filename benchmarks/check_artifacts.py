"""Bench-artifact validation: the CI checks, as an importable module.

Two checks used to live as inline ``python - <<'EOF'`` blocks in
``.github/workflows/ci.yml``; this module gives them a real home with unit
tests (tests/test_check_artifacts.py) so the pipeline's guarantees are
themselves guarded:

* **wellformed** — every bench JSON artifact has its expected ``bench``
  name and non-empty rows; every row honoring an ``identical`` /
  ``no_slower`` contract actually honors it; ``BENCH_runtime.json`` must
  carry ``suspend_frames``, ``victim_frames``, ``compiled_linalg``,
  ``async_overlap`` and ``resource_contention`` rows (the latter with
  the full resource column set, and per-row noise spreads, the perf
  gate's food); ``BENCH_serving.json`` must carry ``serving_poisson``
  continuous-batching rows with the full latency/throughput column set,
  ``serving_compiled`` rows (including workers=4, the dispatch-collapse
  count) with the full compiled column set, plus ``serving_procs``
  multi-process sharding rows with the full procs column set.
* **noise** — the per-row repeat-spread table ((max-min)/min across bench
  repeats) printed to stdout and appended to ``$GITHUB_STEP_SUMMARY``,
  building the noise-floor dataset ``benchmarks/perf_gate`` thresholds
  derive from.

Usage::

    python -m benchmarks.check_artifacts wellformed \
        BENCH_runtime.json BENCH_replay.json BENCH_serving.json
    python -m benchmarks.check_artifacts noise BENCH_runtime.json

Exit code 1 (with a reason on stderr) on any malformed artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

#: columns every continuous-batching (serving_poisson) row must report
POISSON_COLUMNS = (
    "rate", "workers", "p50_tok_ms", "p99_tok_ms",
    "ttft_p50_ms", "ttft_p99_ms", "pooled_tok_s", "dynamic_tok_s",
    "warm_hit_rate", "occupancy", "identical",
)

#: columns every compiled-plan serving row must report (the perf gate
#: consumes compiled_ms/dynamic_ms; the overhead fractions are the
#: dispatch-collapse diagnostic the row exists to publish)
COMPILED_COLUMNS = (
    "workers", "dynamic_ms", "replay_ms", "compiled_ms",
    "speedup_vs_dynamic", "speedup_vs_replay",
    "compiled_overhead_fraction", "replay_overhead_fraction",
    "segments", "fused_tasks", "identical", "noise",
)

#: columns every multi-process serving row must report (the perf gate
#: consumes single_tok_s/procs_tok_s; ``identical`` certifies the sharded
#: streams matched single-process bit-for-bit)
PROCS_COLUMNS = (
    "procs", "workers", "rate", "procs_tok_s", "single_tok_s",
    "speedup", "warm_hit_rate", "identical", "noise",
)

#: columns every resource-contention runtime row must report (the perf
#: gate consumes resources_ms/edges_ms; ``identical`` certifies the two
#: serializations produced the same accumulator contents, and the
#: acquire/wait counters certify the arbiter actually arbitrated)
RESOURCE_COLUMNS = (
    "workers", "tasks", "edges_ms", "resources_ms", "speedup",
    "resource_acquires", "resource_waits", "identical", "noise",
)


class ArtifactError(AssertionError):
    """A bench artifact broke one of the pipeline's contracts."""


def expected_bench(path: str) -> str:
    """``BENCH_runtime.json`` -> ``runtime`` (artifact naming contract)."""
    name = os.path.basename(path)
    if not (name.startswith("BENCH_") and name.endswith(".json")):
        raise ArtifactError(
            f"{path}: cannot infer bench name (want BENCH_<name>.json)")
    return name[len("BENCH_"):-len(".json")]


def _load(path: str) -> Dict:
    with open(path) as fh:
        return json.load(fh)


def check_rows(path: str, out: Dict, bench: str) -> None:
    """The per-file contracts the old inline CI block asserted."""
    if out.get("bench") != bench or not out.get("rows"):
        raise ArtifactError(
            f"{path}: want bench={bench!r} with rows, got "
            f"bench={out.get('bench')!r} rows={len(out.get('rows', []))}")
    rows = out["rows"]
    for row in rows:
        # correctness contracts are booleans stamped by the bench itself:
        # replay/pooled streams bit-identical, warm paths no slower
        if not row.get("identical", True):
            raise ArtifactError(f"{path}: stream diverged in row {row}")
        if not row.get("no_slower", True):
            raise ArtifactError(f"{path}: no_slower violated in row {row}")
    if bench == "runtime":
        if not any(r["bench"] == "suspend_frames" for r in rows):
            raise ArtifactError(f"{path}: missing suspend_frames rows")
        if not any(r["bench"] == "victim_frames" for r in rows):
            raise ArtifactError(f"{path}: missing victim_frames rows")
        if not any(r["bench"] == "compiled_linalg" for r in rows):
            raise ArtifactError(f"{path}: missing compiled_linalg rows")
        if not any(r["bench"] == "async_overlap" for r in rows):
            raise ArtifactError(f"{path}: missing async_overlap rows")
        contention = [r for r in rows if r["bench"] == "resource_contention"]
        if not contention:
            raise ArtifactError(
                f"{path}: missing resource_contention (declarative mutual "
                "exclusion vs edge serialization) rows")
        for row in contention:
            missing = [c for c in RESOURCE_COLUMNS if c not in row]
            if missing:
                raise ArtifactError(
                    f"{path}: resource_contention row missing {missing}: "
                    f"{row}")
            if row["resource_acquires"] < row["tasks"]:
                raise ArtifactError(
                    f"{path}: resource_contention row acquired the "
                    f"accumulator fewer times than it has updates: {row}")
        for row in rows:
            if "noise" not in row:
                raise ArtifactError(
                    f"{path}: row missing noise spread: {row}")
    if bench == "serving":
        compiled = [r for r in rows if r["bench"] == "serving_compiled"]
        if not compiled:
            raise ArtifactError(
                f"{path}: missing serving_compiled (compiled plan) rows")
        if not any(r["workers"] == 4 for r in compiled):
            raise ArtifactError(
                f"{path}: serving_compiled must include a workers=4 row "
                "(the dispatch-collapse worker count)")
        for row in compiled:
            missing = [c for c in COMPILED_COLUMNS if c not in row]
            if missing:
                raise ArtifactError(
                    f"{path}: serving_compiled row missing {missing}: {row}")
        procs = [r for r in rows if r["bench"] == "serving_procs"]
        if not procs:
            raise ArtifactError(
                f"{path}: missing serving_procs (multi-process sharded "
                "serving) rows")
        for row in procs:
            missing = [c for c in PROCS_COLUMNS if c not in row]
            if missing:
                raise ArtifactError(
                    f"{path}: serving_procs row missing {missing}: {row}")
            if not 0.0 <= row["warm_hit_rate"] <= 1.0:
                raise ArtifactError(
                    f"{path}: warm_hit_rate out of range: {row}")
        poisson = [r for r in rows if r["bench"] == "serving_poisson"]
        if not poisson:
            raise ArtifactError(
                f"{path}: missing serving_poisson (continuous batching) "
                "rows")
        for row in poisson:
            missing = [c for c in POISSON_COLUMNS if c not in row]
            if missing:
                raise ArtifactError(
                    f"{path}: serving_poisson row missing {missing}: {row}")
            if not 0.0 <= row["warm_hit_rate"] <= 1.0:
                raise ArtifactError(
                    f"{path}: warm_hit_rate out of range: {row}")


def check_wellformed(paths: List[str]) -> str:
    for path in paths:
        check_rows(path, _load(path), expected_bench(path))
    return f"benchmark artifacts OK ({len(paths)} files)"


def noise_table(path: str) -> Tuple[str, float]:
    """(markdown table, worst spread) over ``path``'s per-row noise."""
    out = _load(path)
    lines = [f"# {out.get('bench', '?')} noise (repeat relative spread)",
             "| bench | workers | noise |", "|---|---|---|"]
    worst = 0.0
    for row in out["rows"]:
        if "noise" not in row:
            raise ArtifactError(f"{path}: row missing noise spread: {row}")
        worst = max(worst, row["noise"])
        lines.append(f"| {row['bench']} | {row['workers']} "
                     f"| {row['noise']:.1%} |")
    lines.append(f"\nworst observed spread: {worst:.1%} — the perf "
                 "gate's thresholds sit above the accumulated floor")
    return "\n".join(lines), worst


def write_summary(text: str) -> None:
    """Append to the GitHub job summary when running in Actions."""
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(text + "\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    wf = sub.add_parser("wellformed",
                        help="validate bench JSON artifact contracts")
    wf.add_argument("paths", nargs="+", metavar="BENCH_<name>.json")
    nz = sub.add_parser("noise",
                        help="print/accumulate the runner-noise table")
    nz.add_argument("path", metavar="BENCH_runtime.json")
    args = ap.parse_args(argv)
    try:
        if args.cmd == "wellformed":
            print(check_wellformed(args.paths))
        else:
            text, _ = noise_table(args.path)
            print(text)
            write_summary(text)
    except (ArtifactError, OSError, json.JSONDecodeError, KeyError) as err:
        print(f"check_artifacts FAIL: {err!r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
