"""Fig. 11 analogue: distributed Cholesky — hybrid victim selection vs
history across sizes and rank counts, plus the per-worker Idle/Comm/Compute
breakdown (Fig. 11d)."""

from __future__ import annotations

import time
from typing import List

from .common import CHOL_CONFIG, CHOL_MULTI, SIZES, build, emit, run


def bench(seeds=(0, 1, 2)) -> List[dict]:
    rows = []
    for conf_name, conf in (("2rank", CHOL_CONFIG), ("4rank", CHOL_MULTI)):
        for size in ("small", "large", "xl"):
            nb = SIZES[size]
            g = build("cholesky", nb, conf["ranks"])
            res, traces = {}, {}
            t0 = time.perf_counter()
            for pol in ("history", "hybrid"):
                trs = [run(g, conf["workers"], conf["ranks"], policy=pol, seed=s)
                       for s in seeds]
                res[pol] = sum(t.makespan for t in trs) / len(trs)
                traces[pol] = trs[0]
            gain = 100 * (res["history"] - res["hybrid"]) / res["history"]
            row = {
                "bench": "fig11", "config": conf_name, "size": size,
                "history_ms": round(res["history"] * 1e3, 2),
                "hybrid_ms": round(res["hybrid"] * 1e3, 2),
                "hybrid_gain_pct": round(gain, 2),
                "us_per_call": round((time.perf_counter() - t0) * 1e6 / (2 * len(seeds)), 1),
            }
            for pol in ("history", "hybrid"):
                b = traces[pol].breakdown_fraction()
                row[f"{pol}_idle"] = round(b.get("idle", 0), 4)
                row[f"{pol}_comm"] = round(b.get("comm", 0), 4)
                row[f"{pol}_compute"] = round(
                    b.get("compute", 0) + b.get("lookahead", 0) + b.get("panel", 0), 4)
            rows.append(row)
    return rows


def main():
    emit(bench())


if __name__ == "__main__":
    main()
